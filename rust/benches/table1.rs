//! **T1-inference** — the Table 1 reproduction: batch-1 inference latency of
//! all six evaluation networks across the engines:
//!
//!   compiled  — AOT HLO + PJRT (the CompiledNN analog; `pjrt` feature)
//!   optimized — folded/fused/arena interpreter (TFLite / RoboDNN analog)
//!   naive     — exact scalar interpreter (tiny-dnn / frugally-deep analog)
//!   legacy    — naive restricted to the RoboDNN/tiny-dnn layer set; `-`
//!               where those libraries print `-` in the paper's Table 1
//!
//! plus the compile-time row (paper Table 1 last row).
//!
//! Engines come from the `EngineKind` registry: kinds this build lacks
//! (compiled without `--features pjrt`) render as `-` instead of failing.
//!
//! Expected shape (paper): compiled wins big on the four small RoboCup nets;
//! the gap narrows/inverts on MobileNetV2/VGG19. Absolute numbers differ
//! from the NAO's Atom E3845 (DESIGN.md substitution 1).

use std::time::Duration;

use compiled_nn::bench::{bench_budget, black_box, print_grid};
use compiled_nn::engine::{build_engine, build_engine_from_spec, Engine, EngineKind, EngineOptions};
use compiled_nn::model::load::load_model;
use compiled_nn::nn::interp::Capabilities;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::util::rng::{golden_seed, SplitMix64};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load_default()?;
    let budget = Duration::from_secs(3);
    let names = ["c_htwk", "c_bh", "detector", "segmenter", "mobilenetv2", "vgg19"];
    // Table-1 column order — shared with main.rs cmd_table1
    let kinds = EngineKind::ALL;

    let mut rows = Vec::new();
    let mut total_compile_ms: Option<f64> = None;
    for name in names {
        let entry = manifest.entry(name)?;
        let mut rng = SplitMix64::new(golden_seed(entry.seed));
        let mut shape = vec![1];
        shape.extend_from_slice(&entry.input_shape);
        let n: usize = shape.iter().product();
        let x = Tensor::from_vec(&shape, rng.uniform_vec(n));
        let big = entry.params > 1_000_000;
        // one spec parse per model, shared by both interpreter kinds
        let spec = load_model(&manifest.models_dir, name)?;

        let mut cells: Vec<Option<f64>> = Vec::new();
        let mut naive_ms = None;
        for kind in kinds {
            if !kind.available() {
                cells.push(None);
                continue;
            }
            let built = match kind {
                EngineKind::Compiled => {
                    build_engine(kind, &manifest, name, &EngineOptions::with_buckets(&[1]))
                }
                _ => build_engine_from_spec(kind, &spec, &EngineOptions::default()),
            };
            let mut engine = match built {
                Ok(e) => e,
                Err(err) => {
                    eprintln!("{name}/{kind}: {err}");
                    cells.push(None);
                    continue;
                }
            };
            // hard-cap the scalar interpreter; relax everything on big nets
            let min_iters = if big {
                2
            } else if kind == EngineKind::Naive {
                3
            } else {
                10
            };
            let r = bench_budget(&format!("{name}/{kind}"), budget, min_iters, || {
                black_box(engine.infer(&x).unwrap());
            });
            println!("{}", r.row());
            if kind == EngineKind::Naive {
                naive_ms = Some(r.mean_ms);
            }
            if kind == EngineKind::Compiled {
                total_compile_ms =
                    Some(total_compile_ms.unwrap_or(0.0) + engine.compile_ms());
            }
            cells.push(Some(r.mean_ms));
        }

        // `-` cells: engines lacking upsample/depthwise (RoboDNN, tiny-dnn)
        let legacy = if Capabilities::LEGACY.supports(&spec) { naive_ms } else { None };
        cells.push(legacy);
        rows.push((name.to_string(), cells));
    }
    rows.push((
        "compile[ms]".to_string(),
        // compile time applies to the compiled engine column only; `-`
        // (not 0.0) whenever no compiled engine was actually measured
        vec![total_compile_ms, None, None, None],
    ));

    print_grid(
        "Table 1 analog — batch-1 inference latency [ms] (last row: total compile ms)",
        &["compiled", "optimized", "naive", "legacy"],
        &rows,
    );
    Ok(())
}
