//! **T1-inference** — the Table 1 reproduction: batch-1 inference latency of
//! all six evaluation networks across the engines:
//!
//!   compiled  — AOT HLO + PJRT (the CompiledNN analog)
//!   optimized — folded/fused/arena interpreter (TFLite / RoboDNN analog)
//!   naive     — exact scalar interpreter (tiny-dnn / frugally-deep analog)
//!   legacy    — naive restricted to the RoboDNN/tiny-dnn layer set; `-`
//!               where those libraries print `-` in the paper's Table 1
//!
//! plus the compile-time row (paper Table 1 last row).
//!
//! Expected shape (paper): compiled wins big on the four small RoboCup nets;
//! the gap narrows/inverts on MobileNetV2/VGG19. Absolute numbers differ
//! from the NAO's Atom E3845 (DESIGN.md substitution 1).

use std::time::Duration;

use compiled_nn::bench::{bench_budget, black_box, print_grid};
use compiled_nn::compiler::exec::{CompileOptions, OptInterp};
use compiled_nn::model::load::load_model;
use compiled_nn::nn::interp::{Capabilities, NaiveInterp};
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::runtime::executor::{CompiledModel, Runtime};
use compiled_nn::util::rng::{golden_seed, SplitMix64};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load_default()?;
    let rt = Runtime::new()?;
    let budget = Duration::from_secs(3);
    let names = ["c_htwk", "c_bh", "detector", "segmenter", "mobilenetv2", "vgg19"];

    let mut rows = Vec::new();
    let mut compile_ms = Vec::new();
    for name in names {
        let entry = manifest.entry(name)?;
        let mut rng = SplitMix64::new(golden_seed(entry.seed));
        let mut shape = vec![1];
        shape.extend_from_slice(&entry.input_shape);
        let n: usize = shape.iter().product();
        let x = Tensor::from_vec(&shape, rng.uniform_vec(n));
        let big = entry.params > 1_000_000;
        let min_iters = if big { 2 } else { 10 };

        // compiled (PJRT execute of the AOT artifact)
        let m = CompiledModel::load_buckets(&rt, &manifest, entry, &[1])?;
        let r = bench_budget(&format!("{name}/compiled"), budget, min_iters, || {
            black_box(m.execute(&rt, &x).unwrap());
        });
        println!("{}", r.row());
        let compiled = r.mean_ms;
        compile_ms.push(Some(m.total_compile_ms()));

        // optimized interpreter
        let spec = load_model(&manifest.models_dir, name)?;
        let mut opt = OptInterp::new(&spec, CompileOptions::default())?;
        let r = bench_budget(&format!("{name}/optimized"), budget, min_iters, || {
            black_box(opt.infer(&x).unwrap());
        });
        println!("{}", r.row());
        let optimized = r.mean_ms;

        // naive exact interpreter (hard-capped on the big nets)
        let naive = NaiveInterp::new(spec.clone())?;
        let r = bench_budget(&format!("{name}/naive"), budget, min_iters.min(3), || {
            black_box(naive.infer(&x).unwrap());
        });
        println!("{}", r.row());
        let naive_ms = r.mean_ms;

        // `-` cells: engines lacking upsample/depthwise (RoboDNN, tiny-dnn)
        let legacy = Capabilities::LEGACY.supports(&spec).then_some(naive_ms);

        rows.push((
            name.to_string(),
            vec![Some(compiled), Some(optimized), Some(naive_ms), legacy],
        ));
    }
    rows.push(("compile[ms]".to_string(), {
        let mut r = compile_ms;
        r.extend([None, None, None].into_iter().take(0));
        // compile time applies to the compiled engine column only
        vec![r.iter().filter_map(|v| *v).sum::<f64>().into(), None, None, None]
    }));

    print_grid(
        "Table 1 analog — batch-1 inference latency [ms] (last row: total compile ms)",
        &["compiled", "optimized", "naive", "legacy"],
        &rows,
    );

    println!("\nper-model compile time [ms] (paper Table 1 last row):");
    for (name, r) in names.iter().zip(rows.iter()) {
        let _ = r;
        let entry = manifest.entry(name)?;
        let m = CompiledModel::load_buckets(&rt, &manifest, entry, &[1])?;
        println!("  {:<14} {:>10.1}", name, m.total_compile_ms());
    }
    Ok(())
}
