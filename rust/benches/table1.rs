//! **T1-inference** — the Table 1 reproduction: batch-1 inference latency of
//! all six evaluation networks across the engines:
//!
//!   compiled  — AOT HLO + PJRT (the CompiledNN analog; `pjrt` feature)
//!   optimized — Program-backed interpreter (TFLite / RoboDNN analog)
//!   naive     — exact scalar interpreter (tiny-dnn / frugally-deep analog)
//!   legacy    — naive restricted to the RoboDNN/tiny-dnn layer set; `-`
//!               where those libraries print `-` in the paper's Table 1
//!
//! plus the compile-time row (paper Table 1 last row).
//!
//! Engines come from the `EngineKind` registry: kinds this build lacks
//! (compiled without `--features pjrt`) render as `-` instead of failing.
//! Without the artifact manifest (plain CI runners) the bench falls back to
//! the built-in `tiny_cnn` so a result always exists.
//!
//! Besides the human-readable grid, every run writes **BENCH_table1.json**
//! (per-engine ns/inference), which CI uploads as an artifact — the
//! cross-PR perf trajectory record.
//!
//! Expected shape (paper): compiled wins big on the four small RoboCup nets;
//! the gap narrows/inverts on MobileNetV2/VGG19. Absolute numbers differ
//! from the NAO's Atom E3845 (DESIGN.md substitution 1).

use std::time::Duration;

use compiled_nn::bench::{bench_budget, black_box, print_grid};
use compiled_nn::engine::{build_engine, build_engine_from_spec, Engine, EngineKind, EngineOptions};
use compiled_nn::model::builder::tiny_cnn;
use compiled_nn::model::load::load_model;
use compiled_nn::nn::interp::Capabilities;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::util::json::Json;
use compiled_nn::util::rng::{golden_seed, SplitMix64};

/// One measured (model, engine) cell for the JSON report.
struct Cell {
    model: String,
    engine: String,
    ns: f64,
}

fn main() -> anyhow::Result<()> {
    match Manifest::load_default() {
        Ok(manifest) => table1(&manifest),
        Err(e) => {
            eprintln!(
                "no artifact manifest ({e}); benching the built-in tiny_cnn so the \
                 perf trajectory still lands in BENCH_table1.json"
            );
            fallback_tiny()
        }
    }
}

fn table1(manifest: &Manifest) -> anyhow::Result<()> {
    let budget = Duration::from_secs(3);
    let names = ["c_htwk", "c_bh", "detector", "segmenter", "mobilenetv2", "vgg19"];
    // Table-1 column order — shared with main.rs cmd_table1
    let kinds = EngineKind::ALL;

    let mut rows = Vec::new();
    let mut json_cells: Vec<Cell> = Vec::new();
    let mut total_compile_ms: Option<f64> = None;
    for name in names {
        let entry = manifest.entry(name)?;
        let mut rng = SplitMix64::new(golden_seed(entry.seed));
        let mut shape = vec![1];
        shape.extend_from_slice(&entry.input_shape);
        let n: usize = shape.iter().product();
        let x = Tensor::from_vec(&shape, rng.uniform_vec(n));
        let big = entry.params > 1_000_000;
        // one spec parse per model, shared by both interpreter kinds
        let spec = load_model(&manifest.models_dir, name)?;

        let mut cells: Vec<Option<f64>> = Vec::new();
        let mut naive_ms = None;
        for kind in kinds {
            if !kind.available() {
                cells.push(None);
                continue;
            }
            let built = match kind {
                EngineKind::Compiled => {
                    build_engine(kind, manifest, name, &EngineOptions::with_buckets(&[1]))
                }
                _ => build_engine_from_spec(kind, &spec, &EngineOptions::default()),
            };
            let mut engine = match built {
                Ok(e) => e,
                Err(err) => {
                    eprintln!("{name}/{kind}: {err}");
                    cells.push(None);
                    continue;
                }
            };
            // hard-cap the scalar interpreter; relax everything on big nets
            let min_iters = if big {
                2
            } else if kind == EngineKind::Naive {
                3
            } else {
                10
            };
            let r = bench_budget(&format!("{name}/{kind}"), budget, min_iters, || {
                black_box(engine.infer(&x).unwrap());
            });
            println!("{}", r.row());
            if kind == EngineKind::Naive {
                naive_ms = Some(r.mean_ms);
            }
            if kind == EngineKind::Compiled {
                total_compile_ms = Some(total_compile_ms.unwrap_or(0.0) + engine.compile_ms());
            }
            json_cells.push(Cell {
                model: name.to_string(),
                engine: kind.as_str().to_string(),
                ns: r.mean_ms * 1e6,
            });
            cells.push(Some(r.mean_ms));
        }

        // `-` cells: engines lacking upsample/depthwise (RoboDNN, tiny-dnn)
        let legacy = if Capabilities::LEGACY.supports(&spec) { naive_ms } else { None };
        if let Some(ms) = legacy {
            json_cells.push(Cell {
                model: name.to_string(),
                engine: "legacy".to_string(),
                ns: ms * 1e6,
            });
        }
        cells.push(legacy);
        rows.push((name.to_string(), cells));
    }
    rows.push((
        "compile[ms]".to_string(),
        // compile time applies to the compiled engine column only; `-`
        // (not 0.0) whenever no compiled engine was actually measured
        vec![total_compile_ms, None, None, None],
    ));

    print_grid(
        "Table 1 analog — batch-1 inference latency [ms] (last row: total compile ms)",
        &["compiled", "optimized", "naive", "legacy"],
        &rows,
    );
    write_json(&json_cells, total_compile_ms)
}

/// Artifact-less path (plain CI runners): the built-in tiny_cnn through the
/// always-available interpreter kinds.
fn fallback_tiny() -> anyhow::Result<()> {
    let budget = Duration::from_secs(2);
    let spec = tiny_cnn(77);
    let mut rng = SplitMix64::new(1);
    let x = Tensor::from_vec(&[1, 8, 8, 3], rng.uniform_vec(8 * 8 * 3));

    let mut json_cells: Vec<Cell> = Vec::new();
    let mut row: Vec<Option<f64>> = Vec::new();
    for kind in [EngineKind::Optimized, EngineKind::Naive] {
        let mut engine = build_engine_from_spec(kind, &spec, &EngineOptions::default())?;
        let r = bench_budget(&format!("tiny_cnn/{kind}"), budget, 10, || {
            black_box(engine.infer(&x).unwrap());
        });
        println!("{}", r.row());
        json_cells.push(Cell {
            model: "tiny_cnn".to_string(),
            engine: kind.as_str().to_string(),
            ns: r.mean_ms * 1e6,
        });
        row.push(Some(r.mean_ms));
    }
    let rows = vec![("tiny_cnn".to_string(), row)];
    print_grid(
        "Table 1 analog (no artifacts) — tiny_cnn batch-1 latency [ms]",
        &["optimized", "naive"],
        &rows,
    );
    write_json(&json_cells, None)
}

/// Machine-readable results → BENCH_table1.json (uploaded as a CI artifact)
/// so per-engine ns/inference is comparable across PRs. Serialized through
/// the repo's own `util::json` writer — no hand-rolled escaping.
fn write_json(cells: &[Cell], compile_ms: Option<f64>) -> anyhow::Result<()> {
    use std::collections::BTreeMap;

    let mut models: BTreeMap<String, Json> = BTreeMap::new();
    for c in cells {
        let entry =
            models.entry(c.model.clone()).or_insert_with(|| Json::Obj(BTreeMap::new()));
        if let Json::Obj(m) = entry {
            m.insert(c.engine.clone(), Json::Num(c.ns));
        }
    }
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("table1".to_string()));
    root.insert("unit".to_string(), Json::Str("ns_per_inference".to_string()));
    root.insert("models".to_string(), Json::Obj(models));
    root.insert("compile_ms".to_string(), compile_ms.map_or(Json::Null, Json::Num));
    std::fs::write("BENCH_table1.json", format!("{}\n", Json::Obj(root)))?;
    println!("wrote BENCH_table1.json");
    Ok(())
}
