//! **SERVE** — coordinator characterization: throughput and latency of the
//! batched serving path on the ball classifier, sweeping the batching
//! deadline. Reproduces the paper's application claim (§4: classify many
//! more ball-candidate patches per frame) as a serving-throughput curve.

use std::time::{Duration, Instant};

use compiled_nn::coordinator::server::{Coordinator, CoordinatorConfig};
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::util::rng::SplitMix64;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load_default()?;
    println!(
        "{:>10} {:>10} {:>12} {:>10} {:>10} {:>10} {:>8}",
        "max_wait", "requests", "throughput", "p50 µs", "p95 µs", "fill", "padded"
    );
    for wait_us in [200u64, 1000, 4000] {
        let cfg = CoordinatorConfig {
            max_wait: Duration::from_micros(wait_us),
            queue_depth: 4096,
            ..CoordinatorConfig::default()
        };
        let coord = Coordinator::start(manifest.clone(), cfg)?;
        let client = coord.register("c_bh")?;
        let item: usize = client.info.input_shape.iter().product();

        // bursty open-ish loop: frames of 24 candidate patches arrive
        // together (the §4 workload shape) and are collected per frame —
        // this is the regime where dynamic batching actually packs.
        let burst = 24usize;
        let frames = 80usize;
        let total = burst * frames;
        let mut rng = SplitMix64::new(3);
        let inputs: Vec<Tensor> = (0..burst)
            .map(|_| Tensor::from_vec(&client.info.input_shape.clone(), rng.uniform_vec(item)))
            .collect();

        let t0 = Instant::now();
        for _ in 0..frames {
            let pending: Vec<_> = inputs
                .iter()
                .map(|x| client.infer_async(x.clone()))
                .collect::<Result<_, _>>()?;
            for rx in pending {
                rx.recv().unwrap()?;
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        let m = coord.metrics("c_bh").unwrap();
        println!(
            "{:>8}µs {:>10} {:>10.0}/s {:>10} {:>10} {:>10.2} {:>8}",
            wait_us,
            total,
            total as f64 / secs,
            m.latency.quantile_us(0.5),
            m.latency.quantile_us(0.95),
            m.mean_batch_fill(),
            m.padded_slots.get()
        );
        coord.shutdown();
        drop(coord);
    }
    println!("\n(longer deadlines trade latency for batch fill; padded slots are \
             the §4 fixed-shape-bucket cost)");
    Ok(())
}
