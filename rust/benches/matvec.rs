//! **A-matvec / DENSE-GRID** — the §3.3 dense-path characterization.
//!
//! Part 1 keeps the paper's Eq. 2 (broadcast) vs Eq. 3 (rotated-diagonal)
//! matrix–vector sweep: the rotated layout turns the inner loop into two
//! contiguous streams (no per-step gather), the CPU analog of the paper's
//! register/shuffle argument.
//!
//! Part 2 is the batch grid behind **BENCH_dense.json**: per-item matvec
//! vs broadcast vs the batch-blocked GEMM microkernel × batch {1, 4, 8,
//! 32} × square/rectangular dims. The per-item matvec re-streams the full
//! weight matrix once per batch element; the MR×NR GEMM tile streams each
//! packed panel once per 4 items — the weight-bandwidth amortization the
//! batched serving path rides on. CI uploads the JSON as an artifact so
//! the gain is tracked across PRs.

use std::collections::BTreeMap;
use std::time::Duration;

use compiled_nn::bench::{bench, bench_budget, black_box, BenchResult};
use compiled_nn::compiler::cost::batch_elems;
use compiled_nn::compiler::kernels::{dense_run, DenseAlgo, DenseTail, Epilogue, WeightPanels};
use compiled_nn::nn::simd::{
    matvec_broadcast, matvec_naive, matvec_rotated, pack_dense_panels,
    pack_dense_panels_any, rotate_diagonals, WeightDtype,
};
use compiled_nn::util::json::Json;
use compiled_nn::util::rng::SplitMix64;

fn eq23_sweep() {
    println!(
        "cost model: batch_elems(k=2, Eq.3) = {}, batch_elems(k=3, Eq.2) = {}",
        batch_elems(2),
        batch_elems(3)
    );
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "n", "naive ms", "Eq.2 ms", "Eq.3 ms", "Eq3/Eq2", "Eq3/naive"
    );
    let mut rng = SplitMix64::new(0xBEEF);
    for n in [4usize, 8, 16, 32, 64, 128, 256, 512] {
        let w = rng.uniform_vec(n * n);
        let x = rng.uniform_vec(n);
        let d = rotate_diagonals(&w, n);
        let mut y = vec![0.0f32; n];
        // scale iteration count to keep each cell ~10 ms of work
        let iters = (20_000_000 / (n * n)).clamp(20, 200_000);

        let rn = bench(&format!("naive/{n}"), 2, 3, || {
            for _ in 0..iters {
                matvec_naive(&w, &x, &mut y);
                black_box(&y);
            }
        });
        let r2 = bench(&format!("eq2/{n}"), 2, 3, || {
            for _ in 0..iters {
                matvec_broadcast(&w, &x, &mut y);
                black_box(&y);
            }
        });
        let r3 = bench(&format!("eq3/{n}"), 2, 3, || {
            for _ in 0..iters {
                matvec_rotated(&d, &x, &mut y);
                black_box(&y);
            }
        });
        println!(
            "{:>6} {:>12.3} {:>12.3} {:>12.3} {:>10.3} {:>10.3}",
            n,
            rn.mean_ms,
            r2.mean_ms,
            r3.mean_ms,
            r3.mean_ms / r2.mean_ms,
            r3.mean_ms / rn.mean_ms
        );
    }
    println!(
        "(Eq3/Eq2 < 1.0 reproduces the paper's register/shuffle argument; \
         both beat the naive row-major walk at larger n)\n"
    );
}

struct Cell {
    key: String,
    ns_per_item: f64,
}

/// ns per batch item from a whole-batch BenchResult.
fn per_item_ns(r: &BenchResult, batch: usize) -> f64 {
    r.mean_ms * 1e6 / batch as f64
}

fn dense_grid() -> anyhow::Result<()> {
    let budget = Duration::from_millis(350);
    let mut rng = SplitMix64::new(0xD15E);
    let mut cells: Vec<Cell> = Vec::new();
    let mut speedups: BTreeMap<String, f64> = BTreeMap::new();
    println!("== dense grid: per-item matvec vs broadcast vs batch-blocked GEMM");
    println!(
        "{:>10} {:>6} {:>12} {:>12} {:>12} {:>10}",
        "dims", "batch", "matvec ns", "bcast ns", "gemm ns", "gemm gain"
    );
    for &(in_dim, out_dim) in &[(256usize, 256usize), (512usize, 128usize)] {
        let dims = format!("{in_dim}x{out_dim}");
        let kernel = rng.uniform_vec(in_dim * out_dim);
        let bias = rng.uniform_vec(out_dim);
        let panels = pack_dense_panels(&kernel, in_dim, out_dim);
        let square = in_dim == out_dim;
        // y = W x orientation for the broadcast matvec: W[i][j] = K[j][i]
        let mut wt = vec![0.0f32; if square { in_dim * in_dim } else { 0 }];
        if square {
            for i in 0..in_dim {
                for j in 0..in_dim {
                    wt[i * in_dim + j] = kernel[j * in_dim + i];
                }
            }
        }
        for &batch in &[1usize, 4, 8, 32] {
            let x = rng.uniform_vec(batch * in_dim);
            let mut out = vec![0.0f32; batch * out_dim];
            let algo = DenseAlgo::Gemm {
                panels: WeightPanels::F32(panels.clone().into()),
                lanes: 4,
                tail: DenseTail::Panels,
            };

            // per-item matvec: the pre-GEMM serving path — one full pass
            // over the packed weights per batch element
            let r_mv = bench_budget(&format!("{dims}/b{batch}/matvec"), budget, 20, || {
                for n in 0..batch {
                    dense_run(
                        &x[n * in_dim..(n + 1) * in_dim],
                        (1, in_dim),
                        &algo,
                        out_dim,
                        Some(&bias),
                        Epilogue::NONE,
                        &mut [],
                        1,
                        &mut out[n * out_dim..(n + 1) * out_dim],
                    );
                }
                black_box(&out);
            });
            let mv_ns = per_item_ns(&r_mv, batch);
            cells.push(Cell { key: format!("{dims}_matvec_b{batch}"), ns_per_item: mv_ns });

            // Eq. 2 broadcast per item (square layers only)
            let bc_ns = if square {
                let r_bc =
                    bench_budget(&format!("{dims}/b{batch}/broadcast"), budget, 20, || {
                        for n in 0..batch {
                            matvec_broadcast(
                                &wt,
                                &x[n * in_dim..(n + 1) * in_dim],
                                &mut out[n * out_dim..(n + 1) * out_dim],
                            );
                        }
                        black_box(&out);
                    });
                let ns = per_item_ns(&r_bc, batch);
                cells.push(Cell {
                    key: format!("{dims}_broadcast_b{batch}"),
                    ns_per_item: ns,
                });
                Some(ns)
            } else {
                None
            };

            // batch-blocked GEMM: one panel pass per 4 items
            let r_gemm = bench_budget(&format!("{dims}/b{batch}/gemm"), budget, 20, || {
                dense_run(
                    &x,
                    (batch, in_dim),
                    &algo,
                    out_dim,
                    Some(&bias),
                    Epilogue::NONE,
                    &mut [],
                    1,
                    &mut out,
                );
                black_box(&out);
            });
            let gemm_ns = per_item_ns(&r_gemm, batch);
            cells.push(Cell { key: format!("{dims}_gemm_b{batch}"), ns_per_item: gemm_ns });

            // cross-check: the tile and per-item paths must agree
            let mut check = vec![0.0f32; batch * out_dim];
            dense_run(
                &x,
                (batch, in_dim),
                &algo,
                out_dim,
                Some(&bias),
                Epilogue::NONE,
                &mut [],
                1,
                &mut check,
            );
            for n in 0..batch {
                dense_run(
                    &x[n * in_dim..(n + 1) * in_dim],
                    (1, in_dim),
                    &algo,
                    out_dim,
                    Some(&bias),
                    Epilogue::NONE,
                    &mut [],
                    1,
                    &mut out[n * out_dim..(n + 1) * out_dim],
                );
            }
            let worst = check
                .iter()
                .zip(&out)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            anyhow::ensure!(worst < 1e-4, "gemm/matvec diverged by {worst} at b{batch}");

            let gain = mv_ns / gemm_ns;
            speedups.insert(format!("speedup_gemm_vs_matvec_{dims}_b{batch}"), gain);
            let bc_str = match bc_ns {
                Some(v) => format!("{v:.1}"),
                None => "-".to_string(),
            };
            println!(
                "{:>10} {:>6} {:>12.1} {:>12} {:>12.1} {:>9.2}×",
                dims, batch, mv_ns, bc_str, gemm_ns, gain
            );
        }
    }
    println!(
        "\n(gemm gain > 1 at batch ≥ 8 is the weight-bandwidth amortization: \
         the per-item matvec re-streams the whole matrix per element, the \
         MR×NR tile streams each panel once per 4 items)"
    );

    // Lane-width sweep (PR 7): the same 512×128 GEMM with panels packed at
    // 4, 8 and 16 lanes — all widths are portable, so every host reports
    // the keyed speedups (autovectorization realizes the wide gain on
    // AVX2/AVX-512 hardware).
    println!("\n== lane-width sweep: 512x128 GEMM, batch 8");
    let (in_dim, out_dim, batch) = (512usize, 128usize, 8usize);
    let kernel = rng.uniform_vec(in_dim * out_dim);
    let bias = rng.uniform_vec(out_dim);
    let x = rng.uniform_vec(batch * in_dim);
    let mut out = vec![0.0f32; batch * out_dim];
    let mut ns_of: BTreeMap<usize, f64> = BTreeMap::new();
    for lanes in [4usize, 8, 16] {
        let algo = DenseAlgo::Gemm {
            panels: WeightPanels::F32(
                pack_dense_panels_any(&kernel, in_dim, out_dim, lanes).into(),
            ),
            lanes,
            tail: DenseTail::Panels,
        };
        let r = bench_budget(&format!("512x128/b{batch}/gemm-w{lanes}"), budget, 20, || {
            dense_run(
                &x,
                (batch, in_dim),
                &algo,
                out_dim,
                Some(&bias),
                Epilogue::NONE,
                &mut [],
                1,
                &mut out,
            );
            black_box(&out);
        });
        let ns = per_item_ns(&r, batch);
        println!("  w{lanes}: {ns:.1} ns/item");
        cells.push(Cell { key: format!("512x128_gemm_w{lanes}_b{batch}"), ns_per_item: ns });
        ns_of.insert(lanes, ns);
    }
    speedups.insert("speedup_w8_vs_w4_512x128".to_string(), ns_of[&4] / ns_of[&8]);
    speedups.insert("speedup_w16_vs_w4_512x128".to_string(), ns_of[&4] / ns_of[&16]);

    // Weight-dtype sweep (dtype-generic weight pipeline): the same 512×128
    // GEMM with panels stored f32 / bf16 / i8 — the bandwidth-for-accuracy
    // trade the §3.3 cost model prices. `weight_bytes` is the resident
    // packed footprint each pass streams (i8 includes its per-channel
    // scale vector); the speedup keys compare per-dtype ns to the f32 row.
    println!("\n== weight-dtype sweep: 512x128 GEMM, batch 8, 4 lanes");
    let mut dt_ns: BTreeMap<&'static str, f64> = BTreeMap::new();
    let mut weight_dtype: BTreeMap<String, Json> = BTreeMap::new();
    for dtype in WeightDtype::ALL {
        let panels = WeightPanels::pack_dense(&kernel, in_dim, out_dim, 4, dtype);
        let bytes = panels.weight_bytes();
        let algo = DenseAlgo::Gemm { panels, lanes: 4, tail: DenseTail::Panels };
        let r = bench_budget(&format!("512x128/b{batch}/gemm-{dtype}"), budget, 20, || {
            dense_run(
                &x,
                (batch, in_dim),
                &algo,
                out_dim,
                Some(&bias),
                Epilogue::NONE,
                &mut [],
                1,
                &mut out,
            );
            black_box(&out);
        });
        let ns = per_item_ns(&r, batch);
        println!("  {:>5}: {ns:.1} ns/item, {bytes} weight bytes", dtype.label());
        cells.push(Cell {
            key: format!("512x128_gemm_{}_b{batch}", dtype.label()),
            ns_per_item: ns,
        });
        dt_ns.insert(dtype.label(), ns);
        let mut m = BTreeMap::new();
        m.insert("ns_per_item".to_string(), Json::Num(ns));
        m.insert("weight_bytes".to_string(), Json::Num(bytes as f64));
        m.insert(
            "bytes_vs_f32".to_string(),
            Json::Num(bytes as f64 / (in_dim as f64 * out_dim as f64 * 4.0)),
        );
        weight_dtype.insert(dtype.label().to_string(), Json::Obj(m));
    }
    for l in ["bf16", "i8"] {
        speedups.insert(format!("speedup_{l}_vs_f32_512x128"), dt_ns["f32"] / dt_ns[l]);
    }

    write_json(&cells, &speedups, &weight_dtype)?;
    Ok(())
}

/// Machine-readable grid → BENCH_dense.json (uploaded as a CI artifact
/// alongside the other bench JSONs).
fn write_json(
    cells: &[Cell],
    speedups: &BTreeMap<String, f64>,
    weight_dtype: &BTreeMap<String, Json>,
) -> anyhow::Result<()> {
    let mut grid = BTreeMap::new();
    for c in cells {
        grid.insert(c.key.clone(), Json::Num(c.ns_per_item));
    }
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("dense".to_string()));
    root.insert("unit".to_string(), Json::Str("ns_per_item".to_string()));
    root.insert("grid".to_string(), Json::Obj(grid));
    root.insert("weight_dtype".to_string(), Json::Obj(weight_dtype.clone()));
    for (k, v) in speedups {
        root.insert(k.clone(), Json::Num(*v));
    }
    std::fs::write("BENCH_dense.json", format!("{}\n", Json::Obj(root)))?;
    println!("wrote BENCH_dense.json");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    eq23_sweep();
    dense_grid()
}
