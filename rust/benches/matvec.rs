//! **A-matvec** — §3.3's Eq. 2 (broadcast) vs Eq. 3 (rotated-diagonal)
//! matrix–vector schemes, swept over matrix sizes. The paper argues Eq. 3
//! wins by one register and one shuffle per step; here the rotated layout
//! turns the inner loop into two contiguous streams (no per-step gather),
//! which is the CPU analog of the same scheduling argument.
//!
//! The §3.3 cost model's predictions (batches/shuffles per scheme) print
//! alongside the measurements for comparison.

use compiled_nn::bench::{bench, black_box};
use compiled_nn::compiler::cost::batch_elems;
use compiled_nn::nn::simd::{matvec_broadcast, matvec_naive, matvec_rotated, rotate_diagonals};
use compiled_nn::util::rng::SplitMix64;

fn main() {
    println!(
        "cost model: batch_elems(k=2, Eq.3) = {}, batch_elems(k=3, Eq.2) = {}",
        batch_elems(2),
        batch_elems(3)
    );
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "n", "naive ms", "Eq.2 ms", "Eq.3 ms", "Eq3/Eq2", "Eq3/naive"
    );
    let mut rng = SplitMix64::new(0xBEEF);
    for n in [4usize, 8, 16, 32, 64, 128, 256, 512] {
        let w = rng.uniform_vec(n * n);
        let x = rng.uniform_vec(n);
        let d = rotate_diagonals(&w, n);
        let mut y = vec![0.0f32; n];
        // scale iteration count to keep each cell ~10 ms of work
        let iters = (20_000_000 / (n * n)).clamp(20, 200_000);

        let rn = bench(&format!("naive/{n}"), 2, 3, || {
            for _ in 0..iters {
                matvec_naive(&w, &x, &mut y);
                black_box(&y);
            }
        });
        let r2 = bench(&format!("eq2/{n}"), 2, 3, || {
            for _ in 0..iters {
                matvec_broadcast(&w, &x, &mut y);
                black_box(&y);
            }
        });
        let r3 = bench(&format!("eq3/{n}"), 2, 3, || {
            for _ in 0..iters {
                matvec_rotated(&d, &x, &mut y);
                black_box(&y);
            }
        });
        println!(
            "{:>6} {:>12.3} {:>12.3} {:>12.3} {:>10.3} {:>10.3}",
            n,
            rn.mean_ms,
            r2.mean_ms,
            r3.mean_ms,
            r3.mean_ms / r2.mean_ms,
            r3.mean_ms / rn.mean_ms
        );
    }
    println!("\n(Eq3/Eq2 < 1.0 reproduces the paper's register/shuffle argument; \
             both beat the naive row-major walk at larger n)");
}
