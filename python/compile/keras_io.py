"""Keras-format model export — the paper's front end reads "an HDF5 file as
written by the Python library Keras" (§3.1). The HDF5 C library is not
available in this image (DESIGN.md substitution 3), so we emit the same
information content the paper consumes:

  <name>.keras.json — Keras *Functional* architecture JSON, the exact
      `model.to_json()` schema (class_name/config/inbound_nodes), and
  the weight blob stays the nnspec `.weights.bin`, with each Keras layer's
      variables located via a `weights_map` section appended to the JSON
      (HDF5 group → (offset, shape) table).

The Rust importer (`model/keras.rs`) parses this subset of the Keras schema
back into a ModelSpec; `tests/test_keras.py` and rust `tests/keras.rs` check
the round trip end to end.
"""

from __future__ import annotations

import json
import os

from .spec import Layer, ModelSpec

_ACT_TO_KERAS = {
    "linear": "linear",
    "relu": "relu",
    "relu6": "relu6",
    "leaky_relu": "leaky_relu",
    "sigmoid": "sigmoid",
    "tanh": "tanh",
}


def _keras_layer(l: Layer, spec: ModelSpec) -> dict:
    cfg: dict = {"name": l.name, "trainable": False, "dtype": "float32"}
    a = l.attrs
    if l.op == "conv2d":
        class_name = "Conv2D"
        cfg.update(
            filters=a["out_ch"],
            kernel_size=[a["kh"], a["kw"]],
            strides=[a["stride"], a["stride"]],
            padding=a["padding"],
            use_bias=bool(a.get("use_bias")),
            activation=_ACT_TO_KERAS[l.activation],
            data_format="channels_last",
        )
    elif l.op == "depthwise_conv2d":
        class_name = "DepthwiseConv2D"
        cfg.update(
            kernel_size=[a["kh"], a["kw"]],
            strides=[a["stride"], a["stride"]],
            padding=a["padding"],
            use_bias=bool(a.get("use_bias")),
            activation=_ACT_TO_KERAS[l.activation],
            depth_multiplier=1,
            data_format="channels_last",
        )
    elif l.op == "dense":
        class_name = "Dense"
        cfg.update(units=a["units"], use_bias="bias" in l.weights,
                   activation=_ACT_TO_KERAS[l.activation])
    elif l.op == "batchnorm":
        class_name = "BatchNormalization"
        cfg.update(axis=-1, epsilon=a.get("epsilon", 1e-3))
    elif l.op == "maxpool":
        class_name = "MaxPooling2D"
        cfg.update(pool_size=[a["kh"], a["kw"]],
                   strides=[a["stride"], a["stride"]], padding="valid")
    elif l.op == "avgpool":
        class_name = "AveragePooling2D"
        cfg.update(pool_size=[a["kh"], a["kw"]],
                   strides=[a["stride"], a["stride"]], padding="valid")
    elif l.op == "globalavgpool":
        class_name = "GlobalAveragePooling2D"
    elif l.op == "upsample":
        class_name = "UpSampling2D"
        cfg.update(size=[a["factor"], a["factor"]],
                   interpolation="nearest")
    elif l.op == "zeropad":
        t, b, lf, r = a["pad"]
        class_name = "ZeroPadding2D"
        cfg.update(padding=[[t, b], [lf, r]])
    elif l.op == "activation":
        class_name = "Activation"
        cfg.update(activation=_ACT_TO_KERAS[l.activation])
    elif l.op == "softmax":
        class_name = "Softmax"
        cfg.update(axis=-1)
    elif l.op == "add":
        class_name = "Add"
    elif l.op == "concat":
        class_name = "Concatenate"
        cfg.update(axis=-1)
    elif l.op == "flatten":
        class_name = "Flatten"
        cfg.update(data_format="channels_last")
    else:
        raise ValueError(f"op {l.op} has no Keras equivalent")

    inbound = [[[i, 0, 0, {}] for i in l.inputs]]
    return {"class_name": class_name, "name": l.name, "config": cfg,
            "inbound_nodes": inbound}


def export_keras(spec: ModelSpec, models_dir: str) -> str:
    """Write `<name>.keras.json` next to the nnspec files; weights reuse
    `<name>.weights.bin`. Returns the JSON path."""
    layers = [
        {
            "class_name": "InputLayer",
            "name": "input",
            "config": {
                "name": "input",
                "batch_input_shape": [None, *spec.input_shape],
                "dtype": "float32",
            },
            "inbound_nodes": [],
        }
    ]
    layers += [_keras_layer(l, spec) for l in spec.layers]

    weights_map = {
        l.name: {k: w.to_json() for k, w in l.weights.items()}
        for l in spec.layers
        if l.weights
    }
    doc = {
        "class_name": "Functional",
        "config": {
            "name": spec.name,
            "layers": layers,
            "input_layers": [["input", 0, 0]],
            "output_layers": [[o, 0, 0] for o in spec.outputs],
        },
        "keras_version": "2.2.4",  # the era the paper targets
        "backend": "tensorflow",
        # substitution for the HDF5 weight groups (DESIGN.md subst. 3):
        "weights_file": f"{spec.name}.weights.bin",
        "weights_map": weights_map,
    }
    path = os.path.join(models_dir, f"{spec.name}.keras.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path
