"""L2 mirror of the paper's §3.5 merging passes, applied to a ModelSpec
before AOT lowering. The Rust `compiler/fuse.rs` implements the identical
transformation for the optimized-interpreter engine; `tests/test_optimize.py`
checks they agree numerically.

Batch normalization is an affine map per feature channel:
    bn(x) = gamma * (x - mean) / sqrt(var + eps) + beta = scale * x + shift

* producer has linear activation  → fold into the producer's weights:
      W'[..., o] = W[..., o] * scale[o],  b' = b * scale + shift
  (depthwise kernels scale along their channel axis instead).
* producer has a nonlinear activation between it and the BN (paper §3.5:
  "the batch normalization is still fused into the other layer and applied
  after the activation") → attach (post_scale, post_shift) to the producer's
  compilation unit; no separate pass over memory remains.
* BN *before* a linear layer is folded into that consumer only when no
  spatial zero-padding can leak the shift (dense or 1×1 conv): the shift
  term becomes an extra bias contribution.
"""

from __future__ import annotations

import copy

import numpy as np

from .spec import Layer, ModelSpec, WeightRef

FOLDABLE_PRODUCERS = ("conv2d", "depthwise_conv2d", "dense")


def _bn_scale_shift(spec: ModelSpec, bn: Layer):
    gamma = spec.weight_array(bn, "gamma")
    beta = spec.weight_array(bn, "beta")
    mean = spec.weight_array(bn, "mean")
    var = spec.weight_array(bn, "var")
    eps = bn.attrs.get("epsilon", 1e-3)
    scale = gamma / np.sqrt(var + eps)
    shift = beta - mean * scale
    return scale.astype(np.float32), shift.astype(np.float32)


class _BlobEditor:
    """Copy-on-write editor over the flat weight blob; appends new tensors
    (e.g. a bias materialized for a previously bias-free conv)."""

    def __init__(self, spec: ModelSpec):
        self.blob = spec.weights.copy()
        self.spec = spec

    def get(self, layer: Layer, key: str) -> np.ndarray:
        ref = layer.weights[key]
        return self.blob[ref.offset : ref.offset + ref.size].reshape(ref.shape)

    def set(self, layer: Layer, key: str, value: np.ndarray) -> None:
        ref = layer.weights[key]
        assert list(value.shape) == list(ref.shape)
        self.blob[ref.offset : ref.offset + ref.size] = value.ravel()

    def append(self, layer: Layer, key: str, value: np.ndarray) -> None:
        ref = WeightRef(len(self.blob), list(value.shape))
        self.blob = np.concatenate([self.blob, value.astype(np.float32).ravel()])
        layer.weights[key] = ref


def _consumers(spec: ModelSpec, name: str) -> list[Layer]:
    return [l for l in spec.layers if name in l.inputs]


def fold_batchnorm(spec: ModelSpec) -> ModelSpec:
    """Return a new spec with every BN merged into an adjacent linear layer
    (weight fold) or attached as post_scale/post_shift (fused affine)."""
    spec = copy.deepcopy(spec)
    blob = _BlobEditor(spec)
    by_name = {l.name: l for l in spec.layers}
    removed: dict[str, str] = {}  # bn name -> replacement producer name

    for bn in [l for l in spec.layers if l.op == "batchnorm"]:
        src = by_name.get(bn.inputs[0])
        if src is None or src.op not in FOLDABLE_PRODUCERS:
            continue
        if len(_consumers(spec, src.name)) != 1:
            continue  # producer output also used raw elsewhere
        if "post_scale" in src.attrs:
            continue  # already carries a fused affine
        scale, shift = _bn_scale_shift(spec, bn)

        if src.activation == "linear":
            kernel = blob.get(src, "kernel")
            if src.op == "depthwise_conv2d":  # [kh, kw, C, 1]
                kernel = kernel * scale[None, None, :, None]
            elif src.op == "conv2d":  # [kh, kw, I, O]
                kernel = kernel * scale[None, None, None, :]
            else:  # dense [in, out]
                kernel = kernel * scale[None, :]
            blob.set(src, "kernel", kernel)
            if "bias" in src.weights:
                blob.set(src, "bias", blob.get(src, "bias") * scale + shift)
            else:
                blob.append(src, "bias", shift)
                src.attrs["use_bias"] = True
        else:
            # nonlinear activation in between: fused post-activation affine
            src.attrs["post_scale"] = True
            blob.append(src, "post_scale_w", scale)
            blob.append(src, "post_shift_w", shift)

        removed[bn.name] = src.name

    # rewire and drop removed BNs
    layers = []
    for l in spec.layers:
        if l.name in removed:
            continue
        l.inputs = [removed.get(i, i) for i in l.inputs]
        layers.append(l)
    outputs = [removed.get(o, o) for o in spec.outputs]
    return ModelSpec(spec.name, spec.input_shape, layers, outputs, spec.seed,
                     blob.blob)
