"""The six evaluation networks from Table 1 of the paper, built from scratch
on the nnspec Builder with deterministic seeded weights.

Paper (NAO V6)                  → here (see DESIGN.md §3 + substitution log)
  C-HTWK  HTWK patch classifier → `c_htwk`   16×16×1
  C-BH    B-Human ball classif. → `c_bh`     32×32×1
  Detector JET-Net robot det.   → `detector` 60×80×3, stride-2 backbone + head
  Segmenter field/non-field     → `segmenter` 80×80×3 encoder/decoder w/ skip
  MobileNetV2 (α=1, no top)     → `mobilenetv2` full stack @ 96×96×3
  VGG19                         → `vgg19`    full stack @ 64×64×3
"""

from __future__ import annotations

from .spec import Builder, ModelSpec


def c_htwk(seed: int = 101) -> ModelSpec:
    b = Builder("c_htwk", [16, 16, 1], seed)
    x = b.conv2d("input", 8, k=3, activation="relu")
    x = b.maxpool(x)
    x = b.conv2d(x, 12, k=3, activation="relu")
    x = b.maxpool(x)
    x = b.flatten(x)
    x = b.dense(x, 32, activation="relu")
    x = b.dense(x, 2)
    x = b.softmax(x)
    return b.finish(x)


def c_bh(seed: int = 102) -> ModelSpec:
    b = Builder("c_bh", [32, 32, 1], seed)
    x = b.conv2d("input", 8, k=3, activation="relu")
    x = b.batchnorm(x)
    x = b.maxpool(x)
    x = b.conv2d(x, 16, k=3, activation="relu")
    x = b.batchnorm(x)
    x = b.maxpool(x)
    x = b.conv2d(x, 16, k=3, activation="relu")
    x = b.maxpool(x)
    x = b.flatten(x)
    x = b.dense(x, 32, activation="relu")
    x = b.dense(x, 1, activation="sigmoid")
    return b.finish(x)


def detector(seed: int = 103) -> ModelSpec:
    """JET-Net-style single-shot detector: stride-2 conv backbone over the
    camera image, 1×1 conv head predicting 5 box params × 3 anchors/cell."""
    b = Builder("detector", [60, 80, 3], seed)
    x = "input"
    for ch, stride in [(16, 2), (24, 1), (32, 2), (48, 1), (64, 2), (128, 1)]:
        x = b.conv2d(x, ch, k=3, stride=stride, activation="leaky_relu")
        x = b.batchnorm(x)
    # head: 8×10 grid, 3 anchors × (4 box + 1 obj) = 15 channels
    x = b.conv2d(x, 15, k=1, activation="sigmoid")
    return b.finish(x)


def segmenter(seed: int = 104) -> ModelSpec:
    """Field/non-field semantic segmentation on 80×80 (paper §4), U-Net-ish:
    3 stride-2 encoder convs, 3 upsample+conv decoder stages, one skip."""
    b = Builder("segmenter", [80, 80, 3], seed)
    e1 = b.conv2d("input", 8, k=3, stride=2, activation="relu")   # 40
    e1 = b.batchnorm(e1)
    e2 = b.conv2d(e1, 16, k=3, stride=2, activation="relu")       # 20
    e2 = b.batchnorm(e2)
    e3 = b.conv2d(e2, 32, k=3, stride=2, activation="relu")       # 10
    d1 = b.upsample(e3, 2)                                        # 20
    d1 = b.conv2d(d1, 16, k=3, activation="relu")
    d1 = b.concat(d1, e2)                                         # skip
    d2 = b.upsample(d1, 2)                                        # 40
    d2 = b.conv2d(d2, 8, k=3, activation="relu")
    d3 = b.upsample(d2, 2)                                        # 80
    d3 = b.conv2d(d3, 8, k=3, activation="relu")
    out = b.conv2d(d3, 2, k=1)
    out = b.softmax(out)
    return b.finish(out)


def _bottleneck(b: Builder, x: str, in_ch: int, out_ch: int, stride: int,
                expand: int) -> str:
    """MobileNetV2 inverted residual block."""
    mid = in_ch * expand
    y = x
    if expand != 1:
        y = b.conv2d(y, mid, k=1, activation="relu6", use_bias=False)
        y = b.batchnorm(y)
    y = b.depthwise_conv2d(y, k=3, stride=stride, activation="relu6")
    y = b.batchnorm(y)
    y = b.conv2d(y, out_ch, k=1, use_bias=False)  # linear bottleneck
    y = b.batchnorm(y)
    if stride == 1 and in_ch == out_ch:
        y = b.add(y, x)
    return y


def mobilenetv2(seed: int = 105) -> ModelSpec:
    """Full MobileNetV2 α=1 without top (paper's eval model), input 96×96×3
    (spatial reduction vs the paper's 224 — see DESIGN.md substitution 5)."""
    b = Builder("mobilenetv2", [96, 96, 3], seed)
    x = b.conv2d("input", 32, k=3, stride=2, activation="relu6", use_bias=False)
    x = b.batchnorm(x)
    # (expansion, out_ch, repeats, first_stride) per the MobileNetV2 paper
    cfg = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
           (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    in_ch = 32
    for expand, out_ch, repeats, first_stride in cfg:
        for i in range(repeats):
            stride = first_stride if i == 0 else 1
            x = _bottleneck(b, x, in_ch, out_ch, stride, expand)
            in_ch = out_ch
    x = b.conv2d(x, 1280, k=1, activation="relu6", use_bias=False)
    x = b.batchnorm(x)
    x = b.globalavgpool(x)
    return b.finish(x)


def vgg19(seed: int = 106) -> ModelSpec:
    """Full VGG19 layer stack (16 conv + 5 pool + 3 dense), input 64×64×3
    (spatial reduction vs the paper's 224 — see DESIGN.md substitution 5)."""
    b = Builder("vgg19", [64, 64, 3], seed)
    x = "input"
    for block, (ch, n) in enumerate([(64, 2), (128, 2), (256, 4), (512, 4),
                                     (512, 4)]):
        for _ in range(n):
            x = b.conv2d(x, ch, k=3, activation="relu")
        x = b.maxpool(x)
    x = b.flatten(x)  # 2*2*512 = 2048
    x = b.dense(x, 4096, activation="relu")
    x = b.dense(x, 4096, activation="relu")
    x = b.dense(x, 1000)
    x = b.softmax(x)
    return b.finish(x)


ALL = {
    "c_htwk": c_htwk,
    "c_bh": c_bh,
    "detector": detector,
    "segmenter": segmenter,
    "mobilenetv2": mobilenetv2,
    "vgg19": vgg19,
}

# Batch buckets lowered per network: the serving workload (§4 ball candidates)
# batches the small classifiers; the big nets run batch-1 like the paper.
BATCH_BUCKETS = {
    "c_htwk": [1, 8, 32],
    "c_bh": [1, 8, 32],
    "detector": [1],
    "segmenter": [1],
    "mobilenetv2": [1],
    "vgg19": [1],
}

# Weights are baked into the HLO as constants below this parameter count
# (the paper's weights-as-immediates). Above it, weights are runtime args.
BAKE_THRESHOLD = 2_000_000


def build(name: str) -> ModelSpec:
    return ALL[name]()
