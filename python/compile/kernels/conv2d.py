"""L1 Pallas convolution kernels.

§3.3: "the operation of a convolutional layer consists of a subdivision of
the 3D input tensor along the width and height dimensions, followed by a
series of multiplications of a kernel matrix with each of the resulting
input vectors. Thus, the matrix-vector-product is the most important
operation in our implementation."

Two kernels follow that exact decomposition:

* `conv1x1` — a 1×1 convolution *is* the matvec: reshape NHWC to
  [B·H·W, C] rows and push them through the rotated-diagonal matvec
  (`matvec.dense_apply`, Eq. 3). Used by model.py for the baked models'
  1×1 heads (detector, segmenter).

* `conv2d_direct` — the general small-window case as a Pallas kernel: the
  grid walks output pixels; each program extracts its input window (the
  paper's "subdivision") and contracts it against the kernel matrix.
  interpret=True (CPU PJRT); tested against the lax.conv oracle, and kept
  for kernel-level experiments rather than wired into the big models
  (grid-per-pixel interpret overhead would swamp XLA's native conv —
  the same reason the paper loses on very large nets).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import matvec as mv_k


def conv1x1(kernel_c_o: np.ndarray, bias, x_nhwc: jax.Array,
            scheme: str = "diag") -> jax.Array:
    """1×1 conv via the Eq. 3 matvec. `kernel_c_o` is [C, O] (numpy, baked);
    x is [B, H, W, C]."""
    b, h, w, c = x_nhwc.shape
    rows = x_nhwc.reshape(b * h * w, c)
    y = mv_k.dense_apply(kernel_c_o, bias, rows, scheme=scheme)
    return y.reshape(b, h, w, kernel_c_o.shape[1])


def _direct_kernel(kh: int, kw: int, x_ref, k_ref, o_ref):
    """One output pixel per program: window-extract + matvec contraction.

    The window overlaps its neighbours, which BlockSpec tiling cannot
    express (blocks stride by their own size), so the program sees the whole
    image row-plane and slices its window — the §3.3 "subdivision of the 3D
    input tensor" — with a dynamic slice, then contracts it against the
    kernel matrix.
    """
    i = pl.program_id(1)
    j = pl.program_id(2)
    c = x_ref.shape[3]
    window = jax.lax.dynamic_slice(x_ref[...], (0, i, j, 0), (1, kh, kw, c))
    row = window.reshape(1, -1)  # the §3.3 "input vector"
    o_ref[...] = (row @ k_ref[...]).reshape(o_ref.shape)


@functools.partial(jax.jit, static_argnames=("kh", "kw"))
def conv2d_direct(x: jax.Array, kernel: jax.Array, kh: int, kw: int) -> jax.Array:
    """VALID, stride-1 direct conv as a Pallas kernel. x [B,H,W,C],
    kernel [kh*kw*C, O] (pre-flattened at compile time)."""
    b, h, w, c = x.shape
    oc = kernel.shape[1]
    oh, ow = h - kh + 1, w - kw + 1
    return pl.pallas_call(
        functools.partial(_direct_kernel, kh, kw),
        grid=(b, oh, ow),
        in_specs=[
            pl.BlockSpec((1, h, w, c), lambda n, i, j: (n, 0, 0, 0)),
            pl.BlockSpec((kh * kw * c, oc), lambda n, i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, oc), lambda n, i, j: (n, i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, oh, ow, oc), x.dtype),
        interpret=True,
    )(x, kernel)


def flatten_kernel_hwio(k_hwio: np.ndarray) -> np.ndarray:
    """[kh, kw, C, O] → [kh·kw·C, O], the kernel-matrix layout of §3.3."""
    kh, kw, c, o = k_hwio.shape
    return np.asarray(k_hwio).reshape(kh * kw * c, o)
