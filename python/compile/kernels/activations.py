"""L1 Pallas kernels for the paper's activation-function approximations
(§3.4).

SSE has no exp instruction, so the paper replaces transcendentals with:

* tanh — the continued-fraction truncation, Eq. 5:
      tanh(x) ≈ (((36x²+6930)x²+270270)x²+2027025)·x /
                ((((x²+630)x²+51975)x²+945945)x²+2027025)
* sigmoid — via tanh, Eq. 4: sigmoid(x) = (tanh(x/2) + 1) / 2
* exp — Schraudolph's IEEE-754 trick [14]: one multiply, one float→int
  conversion, one integer add, then reinterpret the bits as f32.
* softmax — two passes (§3.4): x'_i = exp(x_i) while accumulating Σx',
  then divide. (We subtract the max first for f32 stability; the division
  by the sum cancels the common factor exactly, so it matches the paper's
  math.)

Each function exists in three forms: the raw jnp expression (`*_expr`, used
inside fused layer kernels and by model.py), a standalone Pallas kernel, and
an exact oracle in ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Schraudolph constants for f32: i = A*x + (B - C), bits→f32.
#   A = 2^23 / ln 2 ; B = 127 * 2^23 ; C chosen to minimize RMS error.
SCHRAUDOLPH_A = 8388608.0 / 0.6931471805599453  # 12102203.16...
SCHRAUDOLPH_B = 127.0 * 8388608.0  # 1065353216
SCHRAUDOLPH_C = 366392.0  # RMS-optimal bias (Schraudolph 1999, f32 analog)


def fast_exp_expr(x):
    """Schraudolph exp: multiply, f32→i32 convert, add, bitcast."""
    i = (SCHRAUDOLPH_A * x + (SCHRAUDOLPH_B - SCHRAUDOLPH_C)).astype(jnp.int32)
    return jax.lax.bitcast_convert_type(i, jnp.float32)


def fast_tanh_expr(x):
    """Eq. 5 continued-fraction rational approximation (4 CF steps)."""
    x2 = x * x
    num = (((36.0 * x2 + 6930.0) * x2 + 270270.0) * x2 + 2027025.0) * x
    den = (((x2 + 630.0) * x2 + 51975.0) * x2 + 945945.0) * x2 + 2027025.0
    return num / den


def fast_sigmoid_expr(x):
    """Eq. 4: sigmoid via tanh(x/2)."""
    return (fast_tanh_expr(0.5 * x) + 1.0) * 0.5


def fast_softmax_expr(x, axis=-1):
    """Two-pass softmax on fast_exp (max-shifted; the shift cancels)."""
    e = fast_exp_expr(x - jnp.max(x, axis=axis, keepdims=True))
    return e / jnp.sum(e, axis=axis, keepdims=True)


EXPRS = {
    "exp": fast_exp_expr,
    "tanh": fast_tanh_expr,
    "sigmoid": fast_sigmoid_expr,
    "softmax": fast_softmax_expr,
}


def _ew_kernel(expr, x_ref, o_ref):
    # In-place elementwise pass — the paper's activations are compiled either
    # fused into the producer's store loop or as one load→compute→store sweep.
    o_ref[...] = expr(x_ref[...])


@functools.partial(jax.jit, static_argnames=("name",))
def apply_fast(name: str, x: jax.Array) -> jax.Array:
    """Run activation `name` as a standalone Pallas kernel (interpret)."""
    expr = EXPRS[name]
    return pl.pallas_call(
        functools.partial(_ew_kernel, expr),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)
