# Pure-jnp correctness oracle for the kernels: exact math, no Pallas, no
# approximations. Every kernel test asserts against these.
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def matvec(w: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Exact y[b] = W x[b] for square W [n, n], x [B, n]."""
    return np.asarray(x) @ np.asarray(w).T


def dense(kernel_in_out: np.ndarray, bias, x: np.ndarray) -> np.ndarray:
    y = np.asarray(x) @ np.asarray(kernel_in_out)
    if bias is not None:
        y = y + np.asarray(bias)[None, :]
    return y


def exp(x):
    return jnp.exp(x)


def tanh(x):
    return jnp.tanh(x)


def sigmoid(x):
    return 1.0 / (1.0 + jnp.exp(-x))


def softmax(x, axis=-1):
    e = jnp.exp(x - jnp.max(x, axis=axis, keepdims=True))
    return e / jnp.sum(e, axis=axis, keepdims=True)


EXACT = {"exp": exp, "tanh": tanh, "sigmoid": sigmoid, "softmax": softmax}

# Error bounds the approximations must satisfy (checked by pytest and
# mirrored by `compiled-nn precision` on the rust side).
TANH_MAX_ABS_ERR = 1e-4      # on [-4, 4]
SIGMOID_MAX_ABS_ERR = 1e-4   # on [-8, 8]
EXP_MAX_REL_ERR = 0.04       # Schraudolph ~3.95% max relative error
SOFTMAX_MAX_ABS_ERR = 0.05   # inherits exp's relative error
