"""L1 Pallas kernels for the paper's core operation: the matrix–vector
product (§3.3).

Two schemes, exactly mirroring the paper's Eq. 2 and Eq. 3:

* Eq. 2 ("broadcast"): y = Σ_j W[:, j] ⊙ broadcast(x_j). Needs a broadcast
  temporary per step — on SSE a shuffle into a third register, here an extra
  live tile inside the kernel (k = 3 resident tiles).

* Eq. 3 ("rotated diagonal"): the weight matrix is stored as stacked rotated
  diagonals D[j][i] = W[i, (i+j) mod n], chosen *at compile time* (weights are
  static, so the layout is free — the paper's key observation). Then
      y = Σ_j D[j] ⊙ roll(x, -j)
  keeps x resident and replaces broadcasts with lane rotations (SSE `shufps`
  → VPU roll); one fewer live tile (k = 2), which on the paper's target
  raises the channels-per-batch by 4 and here shrinks the VMEM working set.

Both kernels are written against square n×n tiles; rectangular dense layers
are zero-padded to n = max(in, out) rounded up to LANE. Pallas runs
interpret=True (CPU PJRT has no Mosaic), so these lower to plain HLO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

LANE = 8  # pad unit; on real TPU this would be 128 (lane width)


def pad_to(n: int, unit: int = LANE) -> int:
    return ((n + unit - 1) // unit) * unit


def rotate_diagonals(w: np.ndarray) -> np.ndarray:
    """Pre-permute a square [n, n] matrix into stacked rotated diagonals:
    D[j, i] = W[i, (i + j) % n]. Done once at compile time (numpy)."""
    n = w.shape[0]
    assert w.shape == (n, n)
    i = np.arange(n)
    return np.stack([w[i, (i + j) % n] for j in range(n)], axis=0)


def pad_matrix(w: np.ndarray, n: int) -> np.ndarray:
    """Zero-pad [in_dim, out_dim] (dense layout) to square [n, n] in
    'y = W x' orientation (rows = outputs)."""
    in_dim, out_dim = w.shape
    out = np.zeros((n, n), w.dtype)
    out[:out_dim, :in_dim] = w.T
    return out


def _matvec_diag_kernel(d_ref, x_ref, o_ref):
    """Eq. 3: o[b, i] = Σ_j D[j, i] * x[b, (i+j) % n].

    Resident tiles: x (stays put all steps) + accumulator → k = 2.
    The rotation is realized as a length-n window over the doubled copy
    [x, x] built once outside the loop — on TPU this is the free lane
    rotation of the resident tile (SSE shufps analog); in interpret/CPU
    lowering it turns per-step roll (concat + two slices) into a single
    dynamic slice, the same restructuring as the Rust P1 fix (§Perf P5).
    """
    x = x_ref[...]  # [B, n] — loaded once, never reloaded (paper's scheme)
    n = x.shape[1]
    xx = jnp.concatenate([x, x], axis=1)  # doubled once, not per step

    def body(j, acc):
        xw = jax.lax.dynamic_slice_in_dim(xx, j, n, axis=1)
        return acc + d_ref[j, :][None, :] * xw

    acc = jnp.zeros_like(x)
    o_ref[...] = jax.lax.fori_loop(0, n, body, acc)


def _matvec_bcast_kernel(w_ref, x_ref, o_ref):
    """Eq. 2: o[b, i] = Σ_j W[i, j] * x[b, j] with x_j broadcast across
    lanes each step — the extra broadcast temporary is the third live tile
    the paper's layout avoids."""
    x = x_ref[...]  # [B, n]
    n = x.shape[1]

    def body(j, acc):
        xj = jax.lax.dynamic_slice_in_dim(x, j, 1, axis=1)  # [B, 1] broadcast temp
        return acc + w_ref[:, j][None, :] * xj

    acc = jnp.zeros_like(x)
    o_ref[...] = jax.lax.fori_loop(0, n, body, acc)


@functools.partial(jax.jit, static_argnames=("scheme",))
def _run(d, x, scheme: str):
    kernel = _matvec_diag_kernel if scheme == "diag" else _matvec_bcast_kernel
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(d, x)


def matvec_diag(d: jax.Array, x: jax.Array) -> jax.Array:
    """y[b] = W x[b] with W pre-permuted by `rotate_diagonals` (Eq. 3)."""
    return _run(d, x, "diag")


def matvec_bcast(w: jax.Array, x: jax.Array) -> jax.Array:
    """y[b] = W x[b], column-broadcast scheme (Eq. 2), ablation baseline."""
    return _run(w, x, "bcast")


def dense_apply(kernel_in_out: np.ndarray, bias: np.ndarray | None,
                x: jax.Array, scheme: str = "diag") -> jax.Array:
    """Apply a dense layer ([in, out] kernel) through the Pallas matvec.

    Pads to square n×n at compile time; the padding columns multiply zeros
    and the padding rows are sliced off, so results match `x @ W + b`.
    """
    in_dim, out_dim = kernel_in_out.shape
    n = pad_to(max(in_dim, out_dim))
    w = pad_matrix(np.asarray(kernel_in_out), n)
    xp = jnp.pad(x, ((0, 0), (0, n - in_dim)))
    if scheme == "diag":
        y = matvec_diag(jnp.asarray(rotate_diagonals(w)), xp)
    else:
        y = matvec_bcast(jnp.asarray(w), xp)
    y = y[:, :out_dim]
    if bias is not None:
        y = y + jnp.asarray(bias)[None, :]
    return y


# Heuristic from DESIGN.md: the Pallas kernel is used where the paper's
# scheme applies without blow-up; huge dense layers (VGG19's 4096s) go to
# the XLA-native GEMM, mirroring the paper being beaten on big nets.
MAX_PALLAS_DENSE = 512


def dense_eligible(in_dim: int, out_dim: int) -> bool:
    return max(in_dim, out_dim) <= MAX_PALLAS_DENSE
