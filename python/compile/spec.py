"""nnspec — the model interchange format shared between the Python compile
path and the Rust runtime/interpreters.

A model is a JSON graph (`<name>.json`) plus a raw little-endian f32 weight
blob (`<name>.weights.bin`). The JSON mirrors what the paper reads from Keras
HDF5: architecture + named weight tensors. Offsets index into the blob in
*floats*, not bytes.

Layer ops (all tensors NHWC, conv kernels HWIO, dense kernels [in, out]):
  conv2d, depthwise_conv2d, dense, maxpool, avgpool, globalavgpool,
  upsample, batchnorm, zeropad, activation, softmax, add, concat, flatten

`activation` may also appear as an attribute of conv2d/depthwise_conv2d/
dense layers, in which case it is fused into that layer (paper §3.4).
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

FORMAT = "nnspec-v1"

# Activations understood by every engine. "linear" is identity.
ACTIVATIONS = ("linear", "relu", "relu6", "leaky_relu", "sigmoid", "tanh")


@dataclass
class WeightRef:
    """A named weight tensor stored in the blob."""

    offset: int  # in floats
    shape: list[int]

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n

    def to_json(self) -> dict:
        return {"offset": self.offset, "shape": list(self.shape)}


@dataclass
class Layer:
    name: str
    op: str
    inputs: list[str]
    attrs: dict = field(default_factory=dict)
    weights: dict[str, WeightRef] = field(default_factory=dict)
    activation: str = "linear"

    def to_json(self) -> dict:
        d = {"name": self.name, "op": self.op, "inputs": list(self.inputs)}
        d.update(self.attrs)
        if self.weights:
            d["weights"] = {k: w.to_json() for k, w in self.weights.items()}
        if self.activation != "linear":
            d["activation"] = self.activation
        return d


@dataclass
class ModelSpec:
    name: str
    input_shape: list[int]  # HWC (batch implicit)
    layers: list[Layer]
    outputs: list[str]
    seed: int
    weights: np.ndarray  # flat f32 blob

    @property
    def param_count(self) -> int:
        return int(self.weights.size)

    def layer(self, name: str) -> Layer:
        for l in self.layers:
            if l.name == name:
                return l
        raise KeyError(name)

    def to_json(self) -> dict:
        return {
            "format": FORMAT,
            "name": self.name,
            "seed": self.seed,
            "input": {"shape": list(self.input_shape)},
            "layers": [l.to_json() for l in self.layers],
            "outputs": list(self.outputs),
            "weights_file": f"{self.name}.weights.bin",
            "weights_len": int(self.weights.size),
        }

    def save(self, models_dir: str) -> None:
        os.makedirs(models_dir, exist_ok=True)
        with open(os.path.join(models_dir, f"{self.name}.json"), "w") as f:
            json.dump(self.to_json(), f, indent=1)
        self.weights.astype("<f4").tofile(
            os.path.join(models_dir, f"{self.name}.weights.bin")
        )

    def weight_array(self, layer: Layer, key: str) -> np.ndarray:
        ref = layer.weights[key]
        return self.weights[ref.offset : ref.offset + ref.size].reshape(ref.shape)


def load(models_dir: str, name: str) -> ModelSpec:
    with open(os.path.join(models_dir, f"{name}.json")) as f:
        j = json.load(f)
    assert j["format"] == FORMAT, j["format"]
    layers = []
    for lj in j["layers"]:
        lj = dict(lj)
        lname, op, inputs = lj.pop("name"), lj.pop("op"), lj.pop("inputs")
        weights = {
            k: WeightRef(w["offset"], w["shape"])
            for k, w in lj.pop("weights", {}).items()
        }
        activation = lj.pop("activation", "linear")
        layers.append(Layer(lname, op, inputs, lj, weights, activation))
    blob = np.fromfile(
        os.path.join(models_dir, j["weights_file"]), dtype="<f4"
    )
    assert blob.size == j["weights_len"]
    return ModelSpec(
        j["name"], j["input"]["shape"], layers, j["outputs"], j["seed"], blob
    )


class Builder:
    """Programmatic model construction with He-normal seeded weights.

    Mirrors rust `model/builder.rs`; weight layout in the blob is the layer
    declaration order, within a layer the lexicographic key order used below.
    """

    def __init__(self, name: str, input_shape: list[int], seed: int):
        self.name = name
        self.input_shape = list(input_shape)
        self.seed = seed
        self.rng = np.random.RandomState(seed)
        self.layers: list[Layer] = []
        self.blob: list[np.ndarray] = []
        self.offset = 0
        self._shapes: dict[str, tuple] = {"input": tuple(input_shape)}
        self._n = 0

    # -- weight helpers ----------------------------------------------------
    def _alloc(self, arr: np.ndarray) -> WeightRef:
        ref = WeightRef(self.offset, list(arr.shape))
        self.blob.append(arr.astype(np.float32).ravel())
        self.offset += arr.size
        return ref

    def _he(self, shape, fan_in) -> np.ndarray:
        return self.rng.randn(*shape).astype(np.float32) * np.sqrt(2.0 / fan_in)

    def _name(self, prefix: str) -> str:
        self._n += 1
        return f"{prefix}{self._n}"

    def shape_of(self, name: str) -> tuple:
        return self._shapes[name]

    def _add(self, layer: Layer, out_shape: tuple) -> str:
        self.layers.append(layer)
        self._shapes[layer.name] = out_shape
        return layer.name

    # -- layers ------------------------------------------------------------
    def conv2d(self, x: str, out_ch: int, k: int = 3, stride: int = 1,
               padding: str = "same", activation: str = "linear",
               use_bias: bool = True, name: Optional[str] = None) -> str:
        h, w, c = self._shapes[x]
        kernel = self._alloc(self._he((k, k, c, out_ch), k * k * c))
        weights = {"kernel": kernel}
        if use_bias:
            weights["bias"] = self._alloc(np.zeros(out_ch))
        if padding == "same":
            oh, ow = (h + stride - 1) // stride, (w + stride - 1) // stride
        else:
            oh, ow = (h - k) // stride + 1, (w - k) // stride + 1
        layer = Layer(name or self._name("conv"), "conv2d", [x],
                      {"kh": k, "kw": k, "out_ch": out_ch, "stride": stride,
                       "padding": padding, "use_bias": use_bias},
                      weights, activation)
        return self._add(layer, (oh, ow, out_ch))

    def depthwise_conv2d(self, x: str, k: int = 3, stride: int = 1,
                         padding: str = "same", activation: str = "linear",
                         name: Optional[str] = None) -> str:
        h, w, c = self._shapes[x]
        kernel = self._alloc(self._he((k, k, c, 1), k * k))
        weights = {"kernel": kernel, "bias": self._alloc(np.zeros(c))}
        if padding == "same":
            oh, ow = (h + stride - 1) // stride, (w + stride - 1) // stride
        else:
            oh, ow = (h - k) // stride + 1, (w - k) // stride + 1
        layer = Layer(name or self._name("dwconv"), "depthwise_conv2d", [x],
                      {"kh": k, "kw": k, "stride": stride, "padding": padding,
                       "use_bias": True},
                      weights, activation)
        return self._add(layer, (oh, ow, c))

    def dense(self, x: str, units: int, activation: str = "linear",
              name: Optional[str] = None) -> str:
        shape = self._shapes[x]
        assert len(shape) == 1, f"dense needs flat input, got {shape}"
        kernel = self._alloc(self._he((shape[0], units), shape[0]))
        weights = {"kernel": kernel, "bias": self._alloc(np.zeros(units))}
        layer = Layer(name or self._name("dense"), "dense", [x],
                      {"units": units}, weights, activation)
        return self._add(layer, (units,))

    def batchnorm(self, x: str, name: Optional[str] = None) -> str:
        shape = self._shapes[x]
        c = shape[-1]
        # Non-trivial statistics so folding tests actually exercise the math.
        weights = {
            "beta": self._alloc(self.rng.randn(c) * 0.1),
            "gamma": self._alloc(1.0 + self.rng.randn(c) * 0.1),
            "mean": self._alloc(self.rng.randn(c) * 0.1),
            "var": self._alloc(1.0 + np.abs(self.rng.randn(c)) * 0.1),
        }
        layer = Layer(name or self._name("bn"), "batchnorm", [x],
                      {"epsilon": 1e-3}, weights)
        return self._add(layer, shape)

    def maxpool(self, x: str, k: int = 2, stride: int | None = None,
                name: Optional[str] = None) -> str:
        stride = stride or k
        h, w, c = self._shapes[x]
        if h < k or w < k:
            raise ValueError(f"maxpool window {k} larger than input {h}x{w}")
        layer = Layer(name or self._name("maxpool"), "maxpool", [x],
                      {"kh": k, "kw": k, "stride": stride})
        # VALID pooling dims: identical to h // stride when stride == k,
        # correct when the windows overlap (stride < k).
        return self._add(layer, ((h - k) // stride + 1, (w - k) // stride + 1, c))

    def avgpool(self, x: str, k: int = 2, stride: int | None = None,
                name: Optional[str] = None) -> str:
        stride = stride or k
        h, w, c = self._shapes[x]
        if h < k or w < k:
            raise ValueError(f"avgpool window {k} larger than input {h}x{w}")
        layer = Layer(name or self._name("avgpool"), "avgpool", [x],
                      {"kh": k, "kw": k, "stride": stride})
        return self._add(layer, ((h - k) // stride + 1, (w - k) // stride + 1, c))

    def globalavgpool(self, x: str, name: Optional[str] = None) -> str:
        h, w, c = self._shapes[x]
        layer = Layer(name or self._name("gap"), "globalavgpool", [x], {})
        return self._add(layer, (c,))

    def upsample(self, x: str, factor: int = 2, name: Optional[str] = None) -> str:
        h, w, c = self._shapes[x]
        layer = Layer(name or self._name("up"), "upsample", [x],
                      {"factor": factor})
        return self._add(layer, (h * factor, w * factor, c))

    def zeropad(self, x: str, pad: list[int], name: Optional[str] = None) -> str:
        h, w, c = self._shapes[x]
        t, b, l, r = pad
        layer = Layer(name or self._name("pad"), "zeropad", [x],
                      {"pad": [t, b, l, r]})
        return self._add(layer, (h + t + b, w + l + r, c))

    def activation(self, x: str, fn: str, name: Optional[str] = None) -> str:
        layer = Layer(name or self._name("act"), "activation", [x],
                      {}, activation=fn)
        return self._add(layer, self._shapes[x])

    def softmax(self, x: str, name: Optional[str] = None) -> str:
        layer = Layer(name or self._name("softmax"), "softmax", [x], {})
        return self._add(layer, self._shapes[x])

    def add(self, a: str, b: str, name: Optional[str] = None) -> str:
        assert self._shapes[a] == self._shapes[b], (self._shapes[a], self._shapes[b])
        layer = Layer(name or self._name("add"), "add", [a, b], {})
        return self._add(layer, self._shapes[a])

    def concat(self, a: str, b: str, name: Optional[str] = None) -> str:
        sa, sb = self._shapes[a], self._shapes[b]
        assert sa[:-1] == sb[:-1]
        layer = Layer(name or self._name("concat"), "concat", [a, b], {})
        return self._add(layer, (*sa[:-1], sa[-1] + sb[-1]))

    def flatten(self, x: str, name: Optional[str] = None) -> str:
        shape = self._shapes[x]
        n = int(np.prod(shape))
        layer = Layer(name or self._name("flatten"), "flatten", [x], {})
        return self._add(layer, (n,))

    def finish(self, outputs: list[str] | str) -> ModelSpec:
        if isinstance(outputs, str):
            outputs = [outputs]
        blob = (np.concatenate(self.blob) if self.blob
                else np.zeros(0, np.float32))
        return ModelSpec(self.name, self.input_shape, self.layers, outputs,
                         self.seed, blob)
