"""L2: spec → JAX forward function (the paper's per-network inference code).

The returned function is pure and shape-specialized per batch size, exactly
like the paper's generated code. Dense layers on baked models route through
the L1 Pallas matvec kernel (rotated-diagonal scheme, §3.3); spatial convs
use `lax.conv_general_dilated` (XLA's native conv — our analog of the parts
of the paper's codegen we do not specialize); sigmoid/tanh/softmax use the
§3.4 approximation kernels when `approx=True`.

Weights are either *baked* (numpy constants captured in the closure → HLO
constants, the paper's weights-as-immediates) or passed as runtime arguments
(large nets; see DESIGN.md substitution 2).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .kernels import activations as act_k
from .kernels import conv2d as conv_k
from .kernels import matvec as mv_k
from .spec import Layer, ModelSpec

DIMS = ("NHWC", "HWIO", "NHWC")


@dataclass(frozen=True)
class BuildConfig:
    baked: bool = True       # weights as HLO constants vs runtime args
    approx: bool = True      # §3.4 fast activations
    use_pallas: bool = True  # §3.3 Pallas matvec for eligible dense layers


def weight_arg_order(spec: ModelSpec) -> list[tuple[str, str]]:
    """Deterministic (layer, key) order for weights-as-args models; the Rust
    runtime feeds literals in exactly this order (recorded in the manifest)."""
    order = []
    for l in spec.layers:
        for key in sorted(l.weights):
            order.append((l.name, key))
    return order


def _activation(name: str, approx: bool):
    if name == "linear":
        return lambda x: x
    if name == "relu":
        return lambda x: jnp.maximum(x, 0.0)
    if name == "relu6":
        return lambda x: jnp.clip(x, 0.0, 6.0)
    if name == "leaky_relu":
        return lambda x: jnp.where(x >= 0.0, x, 0.1 * x)
    if name == "sigmoid":
        return act_k.fast_sigmoid_expr if approx else (
            lambda x: 1.0 / (1.0 + jnp.exp(-x)))
    if name == "tanh":
        return act_k.fast_tanh_expr if approx else jnp.tanh
    raise ValueError(f"unknown activation {name}")


def build_forward(spec: ModelSpec, cfg: BuildConfig = BuildConfig()):
    """Returns (fn, example_weights).

    baked:   fn(x) -> tuple of outputs
    unbaked: fn(x, *weights) -> tuple of outputs, weights in
             `weight_arg_order` order (example_weights holds the arrays).
    """
    order = weight_arg_order(spec)
    arrays = {
        (ln, k): spec.weight_array(spec.layer(ln), k) for ln, k in order
    }

    def forward(x, *ws):
        if cfg.baked:
            get = lambda l, k: jnp.asarray(arrays[(l.name, k)])
        else:
            idx = {lk: i for i, lk in enumerate(order)}
            get = lambda l, k: ws[idx[(l.name, k)]]

        env = {"input": x}
        for l in spec.layers:
            a = env[l.inputs[0]]
            if l.op == "conv2d":
                kh, kw, s = l.attrs["kh"], l.attrs["kw"], l.attrs["stride"]
                kshape = spec.layer(l.name).weights["kernel"].shape
                use_1x1 = (cfg.use_pallas and cfg.baked and kh == 1 and kw == 1
                           and s == 1
                           and mv_k.dense_eligible(kshape[2], kshape[3]))
                if use_1x1:
                    # §3.3: 1×1 conv IS the matvec — L1 kernel path
                    kernel = arrays[(l.name, "kernel")].reshape(
                        kshape[2], kshape[3])
                    bias = (arrays[(l.name, "bias")]
                            if l.attrs.get("use_bias") else None)
                    y = conv_k.conv1x1(kernel, bias, a)
                else:
                    k = get(l, "kernel")
                    pad = l.attrs["padding"].upper()
                    y = lax.conv_general_dilated(
                        a, k, (s, s), pad, dimension_numbers=DIMS)
                    if l.attrs.get("use_bias"):
                        y = y + get(l, "bias")
                y = _activation(l.activation, cfg.approx)(y)
            elif l.op == "depthwise_conv2d":
                k, s = get(l, "kernel"), l.attrs["stride"]
                c = k.shape[2]
                k = jnp.transpose(k, (0, 1, 3, 2))  # [kh,kw,C,1] -> [kh,kw,1,C]
                pad = l.attrs["padding"].upper()
                y = lax.conv_general_dilated(
                    a, k, (s, s), pad, dimension_numbers=DIMS,
                    feature_group_count=c)
                if l.attrs.get("use_bias"):
                    y = y + get(l, "bias")
                y = _activation(l.activation, cfg.approx)(y)
            elif l.op == "dense":
                kernel = arrays[(l.name, "kernel")]
                in_dim, out_dim = kernel.shape
                use_pallas = (cfg.use_pallas and cfg.baked
                              and mv_k.dense_eligible(in_dim, out_dim))
                if use_pallas:
                    # L1 kernel: rotated-diagonal matvec over baked weights.
                    bias = (arrays[(l.name, "bias")]
                            if "bias" in spec.layer(l.name).weights else None)
                    y = mv_k.dense_apply(kernel, bias, a, scheme="diag")
                else:
                    y = a @ get(l, "kernel")
                    if "bias" in spec.layer(l.name).weights:
                        y = y + get(l, "bias")
                y = _activation(l.activation, cfg.approx)(y)
            elif l.op == "batchnorm":
                scale = get(l, "gamma") / jnp.sqrt(
                    get(l, "var") + l.attrs.get("epsilon", 1e-3))
                y = (a - get(l, "mean")) * scale + get(l, "beta")
            elif l.op == "maxpool":
                k, s = l.attrs["kh"], l.attrs["stride"]
                y = lax.reduce_window(a, -jnp.inf, lax.max,
                                      (1, k, k, 1), (1, s, s, 1), "VALID")
            elif l.op == "avgpool":
                k, s = l.attrs["kh"], l.attrs["stride"]
                y = lax.reduce_window(a, 0.0, lax.add,
                                      (1, k, k, 1), (1, s, s, 1), "VALID")
                y = y / float(k * k)
            elif l.op == "globalavgpool":
                y = jnp.mean(a, axis=(1, 2))
            elif l.op == "upsample":
                f = l.attrs["factor"]
                y = jnp.repeat(jnp.repeat(a, f, axis=1), f, axis=2)
            elif l.op == "zeropad":
                t, bt, lt, r = l.attrs["pad"]
                y = jnp.pad(a, ((0, 0), (t, bt), (lt, r), (0, 0)))
            elif l.op == "activation":
                y = _activation(l.activation, cfg.approx)(a)
            elif l.op == "softmax":
                y = (act_k.fast_softmax_expr(a) if cfg.approx
                     else jax.nn.softmax(a, axis=-1))
            elif l.op == "add":
                y = a + env[l.inputs[1]]
            elif l.op == "concat":
                y = jnp.concatenate([a, env[l.inputs[1]]], axis=-1)
            elif l.op == "flatten":
                y = a.reshape(a.shape[0], -1)
            else:
                raise ValueError(f"unknown op {l.op}")
            # §3.5: fused post-activation affine (BN merged across activation)
            if l.attrs.get("post_scale"):
                y = y * get(l, "post_scale_w") + get(l, "post_shift_w")
            env[l.name] = y
        return tuple(env[o] for o in spec.outputs)

    example_weights = [arrays[lk] for lk in order]
    return forward, example_weights


def output_shapes(spec: ModelSpec, batch: int,
                  cfg: BuildConfig = BuildConfig()) -> list[list[int]]:
    fn, ws = build_forward(spec, cfg)
    x = jax.ShapeDtypeStruct((batch, *spec.input_shape), jnp.float32)
    args = (x,) if cfg.baked else (x, *[jax.ShapeDtypeStruct(w.shape, jnp.float32) for w in ws])
    out = jax.eval_shape(fn, *args)
    return [list(o.shape) for o in out]
