"""Deterministic test-data generation shared bit-identically with Rust.

SplitMix64 (Steele et al. 2014) seeded streams; `splitmix_uniform` draws
f32 uniforms in [-1, 1) by taking the top 24 bits of each 64-bit output —
the Rust mirror is `util/rng.rs::SplitMix64::next_uniform`.
"""

from __future__ import annotations

import numpy as np

MASK = (1 << 64) - 1


def splitmix64_stream(seed: int, n: int) -> np.ndarray:
    """n raw 64-bit outputs of SplitMix64 starting from `seed`."""
    out = np.empty(n, dtype=np.uint64)
    state = seed & MASK
    for i in range(n):
        state = (state + 0x9E3779B97F4A7C15) & MASK
        z = state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        z = z ^ (z >> 31)
        out[i] = z
    return out


def splitmix_uniform(seed: int, shape) -> np.ndarray:
    """f32 uniforms in [-1, 1): top 24 bits / 2^23 - 1."""
    n = int(np.prod(shape))
    raw = splitmix64_stream(seed, n)
    top24 = (raw >> np.uint64(40)).astype(np.float64)  # [0, 2^24)
    vals = (top24 / float(1 << 23)) - 1.0
    return vals.astype(np.float32).reshape(shape)
