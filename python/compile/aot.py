"""AOT driver: lower every (network, batch-bucket) pair to HLO *text* and
emit the runtime manifest.

HLO text — not `.serialize()` — is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs:
  models/<name>.json + models/<name>.weights.bin   (nnspec, for the Rust
                                                    interpreter engines)
  artifacts/<name>.b<B>.hlo.txt                    (per batch bucket)
  artifacts/golden/<name>.json                     (exact-oracle outputs)
  artifacts/manifest.json

Python runs only here (`make artifacts`); the Rust binary is self-contained
afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import keras_io, networks, optimize
from .model import BuildConfig, build_forward, weight_arg_order
from .spec import ModelSpec


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: baked weights ARE the payload (the paper's
    # weights-as-immediates); the default printer elides them as `{...}`,
    # which would silently zero the model on the rust side.
    return comp.as_hlo_text(print_large_constants=True)


def golden_input(spec: ModelSpec, batch: int) -> np.ndarray:
    """Deterministic test input; the Rust side regenerates it bit-identically
    (SplitMix64-seeded uniform [-1, 1), see util/rng.rs)."""
    from .testdata import splitmix_uniform

    return splitmix_uniform(spec.seed ^ 0xDEADBEEF,
                            (batch, *spec.input_shape))


def lower_model(spec: ModelSpec, batch: int, cfg: BuildConfig):
    fn, ws = build_forward(spec, cfg)
    x_spec = jax.ShapeDtypeStruct((batch, *spec.input_shape), jnp.float32)
    if cfg.baked:
        return jax.jit(fn).lower(x_spec), ws
    w_specs = [jax.ShapeDtypeStruct(w.shape, jnp.float32) for w in ws]
    return jax.jit(fn).lower(x_spec, *w_specs), ws


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--models-dir", default="../models")
    p.add_argument("--only", default=None, help="comma-separated model names")
    args = p.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    os.makedirs(os.path.join(args.out_dir, "golden"), exist_ok=True)
    os.makedirs(args.models_dir, exist_ok=True)

    names = (args.only.split(",") if args.only else list(networks.ALL))
    manifest: dict = {"format": "manifest-v1", "models": {}}

    for name in names:
        t0 = time.time()
        spec = networks.build(name)
        spec.save(args.models_dir)
        keras_io.export_keras(spec, args.models_dir)
        baked = spec.param_count <= networks.BAKE_THRESHOLD
        buckets = networks.BATCH_BUCKETS[name]

        # ---- golden: exact oracle (no approx, no pallas, unfolded) -------
        x1 = golden_input(spec, 1)
        exact_fn, _ = build_forward(
            spec, BuildConfig(baked=True, approx=False, use_pallas=False))
        exact_out = [np.asarray(o) for o in jax.jit(exact_fn)(x1)]
        golden = {
            "name": name,
            "input_seed_xor": "0xDEADBEEF",
            "outputs": [
                {
                    "shape": list(o.shape),
                    "sample": [float(v) for v in o.ravel()[:64]],
                    "sum": float(o.sum()),
                    "absmax": float(np.abs(o).max()),
                }
                for o in exact_out
            ],
        }
        with open(os.path.join(args.out_dir, "golden", f"{name}.json"), "w") as f:
            json.dump(golden, f, indent=1)

        # ---- compiled path: folded + approx + pallas ----------------------
        folded = optimize.fold_batchnorm(spec)
        cfg = BuildConfig(baked=baked, approx=True, use_pallas=True)
        files = {}
        for b in buckets:
            lowered, ws = lower_model(folded, b, cfg)
            text = to_hlo_text(lowered)
            fname = f"{name}.b{b}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            files[str(b)] = {
                "file": fname,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "bytes": len(text),
            }
        out_shapes = [list(np.asarray(o).shape) for o in exact_out]

        # Ablation variant for the baked nets: same folded graph without the
        # Pallas kernels (XLA-native dot/conv only). Quantifies the
        # interpret-mode kernel tax on CPU — see EXPERIMENTS.md §Perf P5;
        # on a real TPU the Mosaic lowering replaces this path entirely.
        variants = {}
        if baked:
            lowered, _ = lower_model(
                folded, 1, BuildConfig(baked=True, approx=True,
                                       use_pallas=False))
            text = to_hlo_text(lowered)
            fname = f"{name}.nopallas.b1.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            variants["nopallas_b1"] = {
                "file": fname,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "bytes": len(text),
            }

        entry = {
            "input_shape": list(spec.input_shape),
            "output_shapes_b1": out_shapes,
            "batches": buckets,
            "baked": baked,
            "approx": True,
            "params": spec.param_count,
            "seed": spec.seed,
            "artifacts": files,
            "spec_file": f"{name}.json",
        }
        if variants:
            entry["variants"] = variants
        if not baked:
            # runtime feeds these (from the *folded* spec blob) as args,
            # in this exact order, after the input literal
            entry["weights_file"] = f"{name}.folded.weights.bin"
            folded.weights.astype("<f4").tofile(
                os.path.join(args.models_dir, f"{name}.folded.weights.bin"))
            entry["weight_args"] = [
                {
                    "layer": ln,
                    "key": k,
                    "offset": folded.layer(ln).weights[k].offset,
                    "shape": folded.layer(ln).weights[k].shape,
                }
                for ln, k in weight_arg_order(folded)
            ]
        manifest["models"][name] = entry
        print(f"[aot] {name}: params={spec.param_count} baked={baked} "
              f"buckets={buckets} ({time.time()-t0:.1f}s)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest for {len(names)} models")


if __name__ == "__main__":
    main()
