# L1 Pallas conv kernels vs the lax.conv oracle (hypothesis sweeps).
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st
from jax import lax

from compile.kernels import conv2d as conv_k

DIMS = ("NHWC", "HWIO", "NHWC")


def exact_conv(x, k_hwio, padding="VALID", stride=1):
    return np.asarray(
        lax.conv_general_dilated(
            jnp.asarray(x), jnp.asarray(k_hwio), (stride, stride), padding,
            dimension_numbers=DIMS,
        )
    )


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    hw=st.integers(2, 10),
    c=st.integers(1, 8),
    o=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv1x1_matches_lax(b, hw, c, o, seed):
    rng = np.random.RandomState(seed)
    x = rng.randn(b, hw, hw, c).astype(np.float32)
    k = rng.randn(1, 1, c, o).astype(np.float32)
    bias = rng.randn(o).astype(np.float32)
    got = np.asarray(conv_k.conv1x1(k.reshape(c, o), bias, jnp.asarray(x)))
    want = exact_conv(x, k) + bias
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv1x1_no_bias():
    rng = np.random.RandomState(0)
    x = rng.randn(2, 4, 4, 8).astype(np.float32)
    k = rng.randn(1, 1, 8, 16).astype(np.float32)
    got = np.asarray(conv_k.conv1x1(k.reshape(8, 16), None, jnp.asarray(x)))
    np.testing.assert_allclose(got, exact_conv(x, k), rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(
    hw=st.integers(3, 8),
    kk=st.integers(1, 3),
    c=st.integers(1, 4),
    o=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_direct_matches_lax(hw, kk, c, o, seed):
    if kk > hw:
        return
    rng = np.random.RandomState(seed)
    x = rng.randn(1, hw, hw, c).astype(np.float32)
    k = rng.randn(kk, kk, c, o).astype(np.float32)
    got = np.asarray(
        conv_k.conv2d_direct(
            jnp.asarray(x), jnp.asarray(conv_k.flatten_kernel_hwio(k)), kk, kk
        )
    )
    want = exact_conv(x, k, padding="VALID")
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_flatten_kernel_layout():
    k = np.arange(2 * 2 * 3 * 4, dtype=np.float32).reshape(2, 2, 3, 4)
    f = conv_k.flatten_kernel_hwio(k)
    assert f.shape == (12, 4)
    # row ordering matches the window.reshape(-1) order used in the kernel
    np.testing.assert_array_equal(f[0], k[0, 0, 0])
    np.testing.assert_array_equal(f[3], k[0, 1, 0])
