# nnspec format: builder shape inference, save/load round-trip, determinism.
import json
import os

import jax
import numpy as np
import pytest

from compile import networks, spec as spec_mod
from compile.model import BuildConfig, build_forward, output_shapes


@pytest.fixture(scope="module")
def small_specs():
    return {n: networks.build(n) for n in ("c_htwk", "c_bh", "segmenter",
                                           "detector")}


def test_builder_shapes_match_jax(small_specs):
    # The Builder's static shape inference must agree with jax.eval_shape.
    for name, s in small_specs.items():
        declared = s.layers[-1]
        shapes = output_shapes(s, batch=2,
                               cfg=BuildConfig(baked=True, approx=False,
                                               use_pallas=False))
        for out_name, got in zip(s.outputs, shapes):
            assert got[0] == 2, name


def test_roundtrip(tmp_path, small_specs):
    for name, s in small_specs.items():
        s.save(str(tmp_path))
        loaded = spec_mod.load(str(tmp_path), name)
        assert loaded.name == s.name
        assert loaded.input_shape == list(s.input_shape)
        assert [l.name for l in loaded.layers] == [l.name for l in s.layers]
        assert [l.op for l in loaded.layers] == [l.op for l in s.layers]
        np.testing.assert_array_equal(loaded.weights, s.weights)
        # attrs survive
        for a, b in zip(loaded.layers, s.layers):
            assert a.activation == b.activation
            assert a.attrs == b.attrs
            assert set(a.weights) == set(b.weights)


def test_roundtrip_forward_identical(tmp_path):
    s = networks.build("c_htwk")
    s.save(str(tmp_path))
    loaded = spec_mod.load(str(tmp_path), "c_htwk")
    cfg = BuildConfig(baked=True, approx=False, use_pallas=False)
    x = np.random.RandomState(0).randn(1, *s.input_shape).astype(np.float32)
    a = jax.jit(build_forward(s, cfg)[0])(x)
    b = jax.jit(build_forward(loaded, cfg)[0])(x)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))


def test_deterministic_weights():
    a, b = networks.build("c_bh"), networks.build("c_bh")
    np.testing.assert_array_equal(a.weights, b.weights)
    c = networks.c_bh(seed=999)
    assert not np.array_equal(a.weights, c.weights)


def test_param_counts():
    # Sanity anchors; these pin the architecture against accidental edits.
    assert networks.build("c_htwk").param_count < 50_000
    assert networks.build("c_bh").param_count < 50_000
    mnv2 = networks.build("mobilenetv2")
    assert 1_500_000 < mnv2.param_count < 3_500_000  # α=1 no-top ≈ 2.2M
    vgg = networks.build("vgg19")
    assert vgg.param_count > 20_000_000
    assert mnv2.param_count > networks.BAKE_THRESHOLD  # weights-as-args
    assert networks.build("c_bh").param_count <= networks.BAKE_THRESHOLD


def test_weight_refs_cover_blob():
    # Every blob float belongs to exactly one weight tensor (no gaps/overlap).
    s = networks.build("c_bh")
    spans = []
    for l in s.layers:
        for w in l.weights.values():
            spans.append((w.offset, w.offset + w.size))
    spans.sort()
    assert spans[0][0] == 0
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 == b0, "gap or overlap in weight blob"
    assert spans[-1][1] == s.param_count


def test_json_is_plain(tmp_path):
    s = networks.build("c_htwk")
    s.save(str(tmp_path))
    with open(os.path.join(str(tmp_path), "c_htwk.json")) as f:
        j = json.load(f)
    assert j["format"] == spec_mod.FORMAT
    assert j["weights_len"] == s.param_count
    assert all("op" in l and "name" in l for l in j["layers"])
