# L2 model construction: compiled config (approx+pallas+folded) must stay
# close to the exact oracle; weights-as-args must equal baked.
import jax
import numpy as np
import pytest

from compile import networks, optimize
from compile.model import BuildConfig, build_forward, weight_arg_order
from compile.aot import golden_input

EXACT = BuildConfig(baked=True, approx=False, use_pallas=False)


@pytest.mark.parametrize("name,tol", [
    ("c_htwk", 0.06),    # softmax output — inherits Schraudolph exp error
    ("c_bh", 2e-3),      # sigmoid output — Eq. 4/5 error
    ("segmenter", 0.06),
    ("detector", 2e-3),
])
def test_compiled_config_close_to_exact(name, tol):
    spec = networks.build(name)
    x = golden_input(spec, 1)
    exact = np.asarray(jax.jit(build_forward(spec, EXACT)[0])(x)[0])
    folded = optimize.fold_batchnorm(spec)
    comp_cfg = BuildConfig(baked=True, approx=True, use_pallas=True)
    comp = np.asarray(jax.jit(build_forward(folded, comp_cfg)[0])(x)[0])
    assert comp.shape == exact.shape
    assert np.abs(comp - exact).max() < tol


def test_args_mode_equals_baked():
    spec = networks.build("c_bh")
    x = golden_input(spec, 2)
    baked_fn, _ = build_forward(spec, BuildConfig(baked=True, approx=False,
                                                  use_pallas=False))
    args_fn, ws = build_forward(spec, BuildConfig(baked=False, approx=False,
                                                  use_pallas=False))
    a = np.asarray(jax.jit(baked_fn)(x)[0])
    b = np.asarray(jax.jit(args_fn)(x, *ws)[0])
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_weight_arg_order_deterministic():
    spec = networks.build("mobilenetv2")
    o1 = weight_arg_order(spec)
    o2 = weight_arg_order(networks.build("mobilenetv2"))
    assert o1 == o2
    assert len(o1) == len(set(o1))


def test_batch_consistency():
    # running batch-3 must equal three batch-1 runs (shape-specialized code,
    # same math) — the batcher relies on this.
    spec = networks.build("c_htwk")
    cfg = BuildConfig(baked=True, approx=False, use_pallas=False)
    fn = jax.jit(build_forward(spec, cfg)[0])
    x = golden_input(spec, 3)
    batched = np.asarray(fn(x)[0])
    singles = np.concatenate([np.asarray(fn(x[i:i + 1])[0]) for i in range(3)])
    np.testing.assert_allclose(batched, singles, rtol=1e-5, atol=1e-6)


def test_golden_input_deterministic():
    spec = networks.build("c_htwk")
    np.testing.assert_array_equal(golden_input(spec, 1), golden_input(spec, 1))


def test_splitmix_pinned_vectors():
    # ABI anchor shared with rust util/rng.rs tests.
    from compile.testdata import splitmix64_stream, splitmix_uniform
    assert [hex(v) for v in splitmix64_stream(1, 2)] == [
        "0x910a2dec89025cc1", "0xbeeb8da1658eec67"]
    np.testing.assert_allclose(
        splitmix_uniform(1, (4,)),
        [0.13312304, 0.49156344, 0.9420054, -0.11128163], atol=1e-7)
