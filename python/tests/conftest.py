# Make `compile.*` importable when pytest runs from the repo root (CI runs
# `python -m pytest python/tests -q` without installing the package).
import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
