# Keras export (§3.1 front-end): schema shape, information preservation.
import json

import pytest

from compile import keras_io, networks, spec as spec_mod


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    d = tmp_path_factory.mktemp("keras")
    s = networks.build("c_bh")
    s.save(str(d))
    path = keras_io.export_keras(s, str(d))
    with open(path) as f:
        return s, json.load(f)


def test_schema_is_functional(exported):
    _, doc = exported
    assert doc["class_name"] == "Functional"
    assert doc["config"]["input_layers"] == [["input", 0, 0]]
    names = [l["name"] for l in doc["config"]["layers"]]
    assert names[0] == "input"
    assert len(set(names)) == len(names)


def test_every_layer_has_keras_class(exported):
    spec, doc = exported
    classes = {l["name"]: l["class_name"] for l in doc["config"]["layers"]}
    assert classes["input"] == "InputLayer"
    for l in spec.layers:
        assert l.name in classes
    assert any(c == "Conv2D" for c in classes.values())
    assert any(c == "BatchNormalization" for c in classes.values())
    assert any(c == "Dense" for c in classes.values())


def test_inbound_nodes_preserve_graph(exported):
    spec, doc = exported
    by_name = {l["name"]: l for l in doc["config"]["layers"]}
    for l in spec.layers:
        inbound = by_name[l.name]["inbound_nodes"][0]
        assert [n[0] for n in inbound] == l.inputs


def test_weights_map_covers_all_weights(exported):
    spec, doc = exported
    wm = doc["weights_map"]
    for l in spec.layers:
        for k, ref in l.weights.items():
            assert wm[l.name][k]["offset"] == ref.offset
            assert wm[l.name][k]["shape"] == list(ref.shape)


def test_all_six_networks_export(tmp_path):
    for name in networks.ALL:
        s = networks.build(name)
        s.save(str(tmp_path))
        path = keras_io.export_keras(s, str(tmp_path))
        with open(path) as f:
            doc = json.load(f)
        assert len(doc["config"]["layers"]) == len(s.layers) + 1  # + InputLayer


def test_activation_names_are_keras_valid(exported):
    _, doc = exported
    valid = {"linear", "relu", "relu6", "leaky_relu", "sigmoid", "tanh",
             "softmax"}
    for l in doc["config"]["layers"]:
        a = l["config"].get("activation")
        if a is not None:
            assert a in valid, a
