# §3.5 merging passes: folding must be numerically equivalent (modulo f32
# associativity) and must remove every foldable BN.
import jax
import numpy as np
import pytest

from compile import networks, optimize
from compile.model import BuildConfig, build_forward
from compile.spec import Builder

EXACT = BuildConfig(baked=True, approx=False, use_pallas=False)


def _run(spec, x):
    return np.asarray(jax.jit(build_forward(spec, EXACT)[0])(x)[0])


@pytest.mark.parametrize("name", ["c_bh", "detector", "segmenter"])
def test_fold_equivalent(name):
    spec = networks.build(name)
    folded = optimize.fold_batchnorm(spec)
    x = np.random.RandomState(1).randn(2, *spec.input_shape).astype(np.float32)
    a, b = _run(spec, x), _run(folded, x)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_fold_removes_bns():
    spec = networks.build("mobilenetv2")
    folded = optimize.fold_batchnorm(spec)
    n_before = sum(l.op == "batchnorm" for l in spec.layers)
    n_after = sum(l.op == "batchnorm" for l in folded.layers)
    assert n_before > 30
    assert n_after == 0, "all MobileNetV2 BNs sit after conv/dwconv"


def test_fold_linear_producer_changes_weights():
    # conv (linear) → BN: fold into kernel+bias, no post_scale.
    b = Builder("t", [8, 8, 3], 0)
    x = b.conv2d("input", 4, k=3)
    x = b.batchnorm(x)
    spec = b.finish(x)
    folded = optimize.fold_batchnorm(spec)
    assert len(folded.layers) == 1
    conv = folded.layers[0]
    assert not conv.attrs.get("post_scale")
    xin = np.random.RandomState(2).randn(1, 8, 8, 3).astype(np.float32)
    np.testing.assert_allclose(_run(spec, xin), _run(folded, xin),
                               rtol=1e-4, atol=1e-5)


def test_fold_across_activation_uses_post_affine():
    # conv+relu → BN: paper §3.5 keeps BN applied *after* the activation,
    # fused into the same unit.
    b = Builder("t", [8, 8, 3], 0)
    x = b.conv2d("input", 4, k=3, activation="relu")
    x = b.batchnorm(x)
    spec = b.finish(x)
    folded = optimize.fold_batchnorm(spec)
    assert len(folded.layers) == 1
    conv = folded.layers[0]
    assert conv.attrs.get("post_scale")
    assert "post_scale_w" in conv.weights and "post_shift_w" in conv.weights
    xin = np.random.RandomState(3).randn(1, 8, 8, 3).astype(np.float32)
    np.testing.assert_allclose(_run(spec, xin), _run(folded, xin),
                               rtol=1e-4, atol=1e-5)


def test_fold_skips_multi_consumer():
    # BN's producer feeds two consumers → folding would change the raw branch.
    b = Builder("t", [8, 8, 4], 0)
    c = b.conv2d("input", 4, k=1)
    bn = b.batchnorm(c)
    other = b.activation(c, "relu")  # second consumer of conv output
    out = b.add(bn, other)
    spec = b.finish(out)
    folded = optimize.fold_batchnorm(spec)
    assert sum(l.op == "batchnorm" for l in folded.layers) == 1
    xin = np.random.RandomState(4).randn(1, 8, 8, 4).astype(np.float32)
    np.testing.assert_allclose(_run(spec, xin), _run(folded, xin),
                               rtol=1e-4, atol=1e-5)


def test_fold_bias_free_conv_gains_bias():
    b = Builder("t", [8, 8, 3], 0)
    x = b.conv2d("input", 4, k=1, use_bias=False)
    x = b.batchnorm(x)
    spec = b.finish(x)
    folded = optimize.fold_batchnorm(spec)
    conv = folded.layers[0]
    assert conv.attrs["use_bias"] and "bias" in conv.weights
    xin = np.random.RandomState(5).randn(1, 8, 8, 3).astype(np.float32)
    np.testing.assert_allclose(_run(spec, xin), _run(folded, xin),
                               rtol=1e-4, atol=1e-5)


def test_fold_depthwise():
    b = Builder("t", [8, 8, 6], 0)
    x = b.depthwise_conv2d("input", k=3)
    x = b.batchnorm(x)
    spec = b.finish(x)
    folded = optimize.fold_batchnorm(spec)
    assert len(folded.layers) == 1
    xin = np.random.RandomState(6).randn(1, 8, 8, 6).astype(np.float32)
    np.testing.assert_allclose(_run(spec, xin), _run(folded, xin),
                               rtol=1e-4, atol=1e-5)


def test_fold_idempotent_blob_consistency():
    spec = networks.build("c_bh")
    folded = optimize.fold_batchnorm(spec)
    # every weight ref still inside the (possibly grown) blob
    for l in folded.layers:
        for w in l.weights.values():
            assert w.offset + w.size <= folded.weights.size
