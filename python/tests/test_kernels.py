# pytest: Pallas kernels vs the exact ref oracle — the CORE correctness
# signal for L1. Hypothesis sweeps shapes and value ranges.
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from compile.kernels import activations as act_k
from compile.kernels import matvec as mv_k
from compile.kernels import ref


# ---------------------------------------------------------------- matvec
@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 6).map(lambda k: 8 * k),  # square sizes, LANE-aligned
    batch=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_diag_matches_ref(n, batch, seed):
    rng = np.random.RandomState(seed)
    w = rng.randn(n, n).astype(np.float32)
    x = rng.randn(batch, n).astype(np.float32)
    d = mv_k.rotate_diagonals(w)
    got = np.asarray(mv_k.matvec_diag(d, x))
    np.testing.assert_allclose(got, ref.matvec(w, x), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 6).map(lambda k: 8 * k),
    batch=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_bcast_matches_ref(n, batch, seed):
    rng = np.random.RandomState(seed)
    w = rng.randn(n, n).astype(np.float32)
    x = rng.randn(batch, n).astype(np.float32)
    got = np.asarray(mv_k.matvec_bcast(w, x))
    np.testing.assert_allclose(got, ref.matvec(w, x), rtol=1e-4, atol=1e-4)


def test_matvec_schemes_agree():
    # Eq. 2 and Eq. 3 are algebraically identical — §3.3's point is that the
    # rotated-diagonal layout changes the *schedule*, not the math.
    rng = np.random.RandomState(0)
    w = rng.randn(32, 32).astype(np.float32)
    x = rng.randn(3, 32).astype(np.float32)
    a = np.asarray(mv_k.matvec_diag(mv_k.rotate_diagonals(w), x))
    b = np.asarray(mv_k.matvec_bcast(w, x))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_rotate_diagonals_layout():
    # D[j, i] = W[i, (i+j) % n] — the exact Eq. 3 permutation.
    w = np.arange(16, dtype=np.float32).reshape(4, 4)
    d = mv_k.rotate_diagonals(w)
    for j in range(4):
        for i in range(4):
            assert d[j, i] == w[i, (i + j) % 4]


@settings(max_examples=15, deadline=None)
@given(
    in_dim=st.integers(1, 80),
    out_dim=st.integers(1, 80),
    batch=st.integers(1, 4),
    scheme=st.sampled_from(["diag", "bcast"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_apply_rectangular(in_dim, out_dim, batch, scheme, seed):
    # Rectangular layers are zero-padded to square; results must be exact.
    rng = np.random.RandomState(seed)
    k = rng.randn(in_dim, out_dim).astype(np.float32)
    b = rng.randn(out_dim).astype(np.float32)
    x = rng.randn(batch, in_dim).astype(np.float32)
    got = np.asarray(mv_k.dense_apply(k, b, x, scheme=scheme))
    np.testing.assert_allclose(got, ref.dense(k, b, x), rtol=1e-4, atol=1e-4)


def test_dense_apply_no_bias():
    rng = np.random.RandomState(7)
    k = rng.randn(24, 10).astype(np.float32)
    x = rng.randn(2, 24).astype(np.float32)
    got = np.asarray(mv_k.dense_apply(k, None, x))
    np.testing.assert_allclose(got, ref.dense(k, None, x), rtol=1e-4, atol=1e-4)


def test_pad_to():
    assert mv_k.pad_to(1) == 8
    assert mv_k.pad_to(8) == 8
    assert mv_k.pad_to(9) == 16


# ---------------------------------------------------------- activations
def test_fast_tanh_bound():
    x = np.linspace(-4, 4, 4001, dtype=np.float32)
    got = np.asarray(act_k.apply_fast("tanh", x))
    err = np.abs(got - np.asarray(ref.tanh(x)))
    assert err.max() < ref.TANH_MAX_ABS_ERR, err.max()


def test_fast_sigmoid_bound():
    x = np.linspace(-8, 8, 4001, dtype=np.float32)
    got = np.asarray(act_k.apply_fast("sigmoid", x))
    err = np.abs(got - np.asarray(ref.sigmoid(x)))
    assert err.max() < ref.SIGMOID_MAX_ABS_ERR, err.max()


def test_schraudolph_exp_bound():
    x = np.linspace(-10, 10, 4001, dtype=np.float32)
    got = np.asarray(act_k.apply_fast("exp", x))
    rel = np.abs(got - np.asarray(ref.exp(x))) / np.asarray(ref.exp(x))
    assert rel.max() < ref.EXP_MAX_REL_ERR, rel.max()


def test_fast_softmax_bound_and_normalization():
    rng = np.random.RandomState(3)
    x = (rng.randn(16, 10) * 3).astype(np.float32)
    got = np.asarray(act_k.apply_fast("softmax", x))
    np.testing.assert_allclose(got.sum(axis=-1), 1.0, atol=1e-5)
    err = np.abs(got - np.asarray(ref.softmax(x)))
    assert err.max() < ref.SOFTMAX_MAX_ABS_ERR, err.max()


@settings(max_examples=15, deadline=None)
@given(
    shape=st.tuples(st.integers(1, 4), st.integers(1, 33)),
    scale=st.floats(0.1, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_activation_kernels_random_shapes(shape, scale, seed):
    rng = np.random.RandomState(seed)
    x = (rng.randn(*shape) * scale).astype(np.float32)
    for name, bound in [("tanh", 2e-4), ("sigmoid", 2e-4)]:
        got = np.asarray(act_k.apply_fast(name, x))
        exact = np.asarray(ref.EXACT[name](x))
        assert np.abs(got - exact).max() < bound


def test_tanh_is_odd_and_bounded():
    x = np.linspace(-4, 4, 101, dtype=np.float32)
    y = np.asarray(act_k.apply_fast("tanh", x))
    np.testing.assert_allclose(y, -y[::-1], atol=1e-6)  # odd function
    assert np.all(np.abs(y) <= 1.0 + 1e-5)
