//! §Perf instrumentation (P3/P5): batch-size amortization of the PJRT
//! dispatch floor on the tiny nets, and the interpret-mode Pallas kernel
//! tax (pallas vs XLA-native variant of the same folded graph).
//!
//! ```bash
//! cargo run --release --example batch_amortization
//! ```

use std::time::Instant;

use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::runtime::executor::{CompiledModel, Runtime};
use compiled_nn::util::rng::SplitMix64;

fn main() -> anyhow::Result<()> {
    let m = Manifest::load_default()?;
    let rt = Runtime::new()?;

    println!("== P3: batch amortization (compiled engine)");
    for name in ["c_htwk", "c_bh"] {
        let entry = m.entry(name)?;
        let model = CompiledModel::load(&rt, &m, name)?;
        for b in [1usize, 8, 32] {
            let mut rng = SplitMix64::new(1);
            let mut shape = vec![b];
            shape.extend_from_slice(&entry.input_shape);
            let n: usize = shape.iter().product();
            let x = Tensor::from_vec(&shape, rng.uniform_vec(n));
            for _ in 0..20 {
                model.execute(&rt, &x)?;
            }
            let iters = 2000 / b.max(1);
            let t = Instant::now();
            for _ in 0..iters {
                model.execute(&rt, &x)?;
            }
            let us = t.elapsed().as_secs_f64() * 1e6 / iters as f64;
            println!("{name} b{b}: {:>8.1} µs/batch = {:>7.2} µs/item", us, us / b as f64);
        }
    }

    println!("\n== P5: interpret-mode Pallas kernel tax (batch 1)");
    println!("{:<12} {:>12} {:>14} {:>8}", "model", "pallas µs", "xla-native µs", "tax");
    for name in ["c_htwk", "c_bh", "detector", "segmenter"] {
        let entry = m.entry(name)?;
        // regular artifact (pallas kernels inside)
        let model = CompiledModel::load_buckets(&rt, &m, entry, &[1])?;
        // nopallas variant compiled directly from its HLO file
        let var = m
            .artifacts_dir
            .join(format!("{name}.nopallas.b1.hlo.txt"));
        let (exe, _) = rt.compile_hlo(&var)?;

        let mut rng = SplitMix64::new(2);
        let mut shape = vec![1usize];
        shape.extend_from_slice(&entry.input_shape);
        let n: usize = shape.iter().product();
        let x = Tensor::from_vec(&shape, rng.uniform_vec(n));

        let t_pallas = {
            for _ in 0..20 {
                model.execute(&rt, &x)?;
            }
            let t = Instant::now();
            for _ in 0..500 {
                model.execute(&rt, &x)?;
            }
            t.elapsed().as_secs_f64() * 1e6 / 500.0
        };
        let t_native = {
            let buf = rt.client().buffer_from_host_buffer::<f32>(x.data(), x.shape(), None)?;
            for _ in 0..20 {
                exe.execute_b(&[&buf])?[0][0].to_literal_sync()?;
            }
            let t = Instant::now();
            for _ in 0..500 {
                exe.execute_b(&[&buf])?[0][0].to_literal_sync()?;
            }
            t.elapsed().as_secs_f64() * 1e6 / 500.0
        };
        println!(
            "{:<12} {:>12.1} {:>14.1} {:>7.2}×",
            name,
            t_pallas,
            t_native,
            t_pallas / t_native
        );
    }
    println!("\n(the tax is the CPU interpret-mode cost of the in-HLO Pallas loops; a\n\
             real TPU Mosaic lowering replaces exactly these ops — see EXPERIMENTS.md P5)");
    Ok(())
}
