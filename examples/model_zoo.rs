//! Model zoo walk-through: register every evaluation network with the
//! coordinator (exercising the compile cache), print the §3 analysis for
//! each (folding, memory plan, cost model), and run one inference through
//! the serving path.
//!
//! ```bash
//! cargo run --release --example model_zoo
//! ```

use compiled_nn::compiler::{cost, fuse, memory};
use compiled_nn::coordinator::server::{Coordinator, CoordinatorConfig};
use compiled_nn::model::load::load_model;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::util::rng::SplitMix64;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load_default()?;
    let coord = Coordinator::start(manifest.clone(), CoordinatorConfig::default())?;
    let mut rng = SplitMix64::new(1);

    println!(
        "{:<14} {:>10} {:>8} {:>11} {:>9} {:>10} {:>10} {:>9}",
        "model", "params", "layers", "compile ms", "BN→0", "mem saved", "MACs(M)", "serve ms"
    );
    for name in manifest.models.keys() {
        let spec = load_model(&manifest.models_dir, name)?;
        let folded = fuse::fold_batchnorm(&spec);
        let plan = memory::plan(&folded, true)?;
        let naive_plan = memory::plan(&folded, false)?;
        let saved = 100.0 * (1.0 - plan.peak_elements() as f64 / naive_plan.naive_total as f64);
        let macs = cost::total_macs(&folded) as f64 / 1e6;

        // through the serving path (registers → compiles → one inference)
        let client = coord.register(name)?;
        let item: usize = client.info.input_shape.iter().product();
        let x = Tensor::from_vec(&client.info.input_shape.clone(), rng.uniform_vec(item));
        let t = std::time::Instant::now();
        let _out = client.infer(x)?;
        let serve_ms = t.elapsed().as_secs_f64() * 1e3;

        println!(
            "{:<14} {:>10} {:>8} {:>11.1} {:>4}→{:<4} {:>9.1}% {:>10.1} {:>9.2}",
            name,
            spec.param_count(),
            spec.layers.len(),
            client.info.compile_ms,
            fuse::bn_count(&spec),
            fuse::bn_count(&folded),
            saved,
            macs,
            serve_ms
        );
    }

    // registry idempotency: re-registering returns the existing client
    // without touching the executor (the compile cache additionally dedups
    // artifact-identical loads inside the executor thread).
    let t = std::time::Instant::now();
    let again = coord.register("c_bh")?;
    println!(
        "\nre-register c_bh: returned existing client in {:.3} ms (original compile was {:.1} ms)",
        t.elapsed().as_secs_f64() * 1e3,
        again.info.compile_ms
    );
    print!("\nserving metrics:\n{}", coord.render_metrics());
    coord.shutdown();
    Ok(())
}
