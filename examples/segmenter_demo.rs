//! Field segmentation demo — the paper's 80×80 field/non-field network on a
//! synthetic pitch image, run through all three engines, with an ASCII
//! rendering of the predicted mask and agreement statistics.
//!
//! ```bash
//! cargo run --release --example segmenter_demo
//! ```

use compiled_nn::compiler::exec::{CompileOptions, OptInterp};
use compiled_nn::model::load::load_model;
use compiled_nn::nn::interp::NaiveInterp;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::runtime::executor::{CompiledModel, Runtime};
use compiled_nn::util::rng::SplitMix64;

const S: usize = 80;

/// Synthetic camera image: green-ish field in the lower ~60%, bright sky
/// above a noisy horizon, plus a few field lines.
fn synth_pitch(rng: &mut SplitMix64) -> (Tensor, Vec<bool>) {
    let mut data = vec![0.0f32; S * S * 3];
    let mut truth = vec![false; S * S];
    for y in 0..S {
        let horizon = 28 + (rng.next_uniform() * 3.0) as isize;
        for x in 0..S {
            let i = (y * S + x) * 3;
            let is_field = (y as isize) > horizon;
            truth[y * S + x] = is_field;
            if is_field {
                // field: strong G, weak R/B (+ white lines)
                let line = y % 20 == 0 || x % 26 == 0;
                let g = if line { 0.9 } else { rng.range(0.45, 0.7) };
                data[i] = if line { 0.9 } else { rng.range(0.05, 0.2) };
                data[i + 1] = g;
                data[i + 2] = if line { 0.9 } else { rng.range(0.05, 0.2) };
            } else {
                // sky/stands: bright, desaturated
                let v = rng.range(0.6, 0.95);
                data[i] = v;
                data[i + 1] = v * rng.range(0.85, 1.0);
                data[i + 2] = v;
            }
        }
    }
    (Tensor::from_vec(&[1, S, S, 3], data), truth)
}

fn mask_from(out: &Tensor) -> Vec<bool> {
    // output [1, 80, 80, 2] softmax; class 1 = field
    out.data()
        .chunks_exact(2)
        .map(|p| p[1] > p[0])
        .collect()
}

fn render(mask: &[bool]) {
    for y in (0..S).step_by(4) {
        let mut line = String::new();
        for x in (0..S).step_by(2) {
            line.push(if mask[y * S + x] { '█' } else { '·' });
        }
        println!("{line}");
    }
}

fn agreement(a: &[bool], b: &[bool]) -> f64 {
    a.iter().zip(b).filter(|(x, y)| x == y).count() as f64 / a.len() as f64
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load_default()?;
    let mut rng = SplitMix64::new(31337);
    let (img, _truth) = synth_pitch(&mut rng);

    // compiled engine
    let rt = Runtime::new()?;
    let model = CompiledModel::load(&rt, &manifest, "segmenter")?;
    let t = std::time::Instant::now();
    let compiled = model.execute(&rt, &img)?;
    let compiled_ms = t.elapsed().as_secs_f64() * 1e3;
    let mask_c = mask_from(&compiled[0]);

    // interpreters
    let spec = load_model(&manifest.models_dir, "segmenter")?;
    let naive_out = NaiveInterp::new(spec.clone())?.infer(&img)?;
    let mask_n = mask_from(&naive_out[0]);
    let mut opt = OptInterp::new(&spec, CompileOptions::default())?;
    let opt_out = opt.infer(&img)?;
    let mask_o = mask_from(&opt_out[0]);

    println!("predicted field mask (compiled engine, {compiled_ms:.2} ms/frame):\n");
    render(&mask_c);
    println!("\nfield coverage: {:.1}%", 100.0 * mask_c.iter().filter(|&&v| v).count() as f64 / mask_c.len() as f64);
    println!("engine agreement (mask pixels):");
    println!("  compiled vs naive:     {:.2}%", 100.0 * agreement(&mask_c, &mask_n));
    println!("  optimized vs naive:    {:.2}%", 100.0 * agreement(&mask_o, &mask_n));
    println!("max |Δ| on softmax maps:");
    println!("  compiled vs naive:     {:.2e}", naive_out[0].max_abs_diff(&compiled[0]));
    println!("  optimized vs naive:    {:.2e}", naive_out[0].max_abs_diff(&opt_out[0]));
    println!("\n(untrained seeded weights — the mask is arbitrary; what matters is \
             that three independent execution paths agree within §3.4 bounds)");
    Ok(())
}
