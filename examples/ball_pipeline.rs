//! **End-to-end driver (E2E-ball)** — the application the paper builds the
//! compiler for (§4): a RoboCup vision pipeline that generates many ball
//! candidate patches per camera frame and must classify all of them inside
//! the frame budget.
//!
//! Pipeline, all layers composing:
//!   synthetic camera frames (SplitMix-seeded, with injected bright discs)
//!   → candidate generator (brightness-peak scan, the "rather sensitive"
//!     generator from §4)
//!   → L3 coordinator: dynamic batching over the compiled c_bh classifier
//!   → per-frame decisions + serving metrics.
//!
//! Reports patches/frame, frame latency, and throughput — the paper's
//! "classify many more ball candidate patches per frame" claim, measured.
//!
//! ```bash
//! cargo run --release --example ball_pipeline [frames] [offered_fps]
//! ```

use std::time::{Duration, Instant};

use compiled_nn::coordinator::server::{Coordinator, CoordinatorConfig};
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::util::rng::SplitMix64;

const FRAME_H: usize = 120;
const FRAME_W: usize = 160;
const PATCH: usize = 32;

/// A synthetic grayscale camera frame with `n_balls` bright discs.
fn synth_frame(rng: &mut SplitMix64, n_balls: usize) -> (Vec<f32>, Vec<(usize, usize)>) {
    let mut img = vec![0.0f32; FRAME_H * FRAME_W];
    for v in img.iter_mut() {
        *v = rng.range(0.0, 0.25); // sensor noise
    }
    let mut truths = Vec::new();
    for _ in 0..n_balls {
        let cy = PATCH / 2 + rng.below(FRAME_H - PATCH);
        let cx = PATCH / 2 + rng.below(FRAME_W - PATCH);
        let r = 4.0 + rng.range(0.0, 4.0);
        for dy in -(r as isize)..=(r as isize) {
            for dx in -(r as isize)..=(r as isize) {
                if (dy * dy + dx * dx) as f32 <= r * r {
                    let y = (cy as isize + dy) as usize;
                    let x = (cx as isize + dx) as usize;
                    if y < FRAME_H && x < FRAME_W {
                        img[y * FRAME_W + x] = rng.range(0.7, 1.0);
                    }
                }
            }
        }
        truths.push((cy, cx));
    }
    (img, truths)
}

/// Brightness-peak candidate generator: coarse 8×8 grid scan, emits a patch
/// wherever local mean brightness exceeds a (deliberately low) threshold —
/// sensitive on purpose, like the paper's.
fn candidates(img: &[f32]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let step = 8;
    for gy in (PATCH / 2..FRAME_H - PATCH / 2).step_by(step) {
        for gx in (PATCH / 2..FRAME_W - PATCH / 2).step_by(step) {
            let mut s = 0.0;
            for dy in 0..step {
                for dx in 0..step {
                    s += img[(gy + dy - step / 2) * FRAME_W + gx + dx - step / 2];
                }
            }
            if s / (step * step) as f32 > 0.139 {
                out.push((gy, gx));
            }
        }
    }
    out
}

fn extract_patch(img: &[f32], cy: usize, cx: usize) -> Tensor {
    let mut data = vec![0.0f32; PATCH * PATCH];
    for y in 0..PATCH {
        for x in 0..PATCH {
            data[y * PATCH + x] = img[(cy - PATCH / 2 + y) * FRAME_W + (cx - PATCH / 2 + x)];
        }
    }
    Tensor::from_vec(&[PATCH, PATCH, 1], data)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_frames: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(120);
    let offered_fps: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(30.0);

    let manifest = Manifest::load_default()?;
    let coord = Coordinator::start(
        manifest,
        CoordinatorConfig { max_wait: Duration::from_micros(500), queue_depth: 4096 },
    )?;
    let t0 = Instant::now();
    let client = coord.register("c_bh")?;
    println!(
        "registered ball classifier: compile {:.1} ms, buckets {:?}",
        client.info.compile_ms, client.info.buckets
    );

    let mut rng = SplitMix64::new(2024);
    let mut frame_lat = Vec::new();
    let mut total_patches = 0usize;
    let mut total_hits = 0usize;
    let frame_gap = Duration::from_secs_f64(1.0 / offered_fps);
    let run_start = Instant::now();

    for f in 0..n_frames {
        let frame_start = Instant::now();
        let n_balls = rng.below(3);
        let (img, truths) = synth_frame(&mut rng, n_balls);
        let cands = candidates(&img);
        total_patches += cands.len();

        // submit every candidate; the coordinator batches them (§4 claim)
        let pending: Vec<_> = cands
            .iter()
            .map(|&(cy, cx)| client.infer_async(extract_patch(&img, cy, cx)))
            .collect::<Result<_, _>>()?;
        let mut best: Option<(f32, (usize, usize))> = None;
        for (rx, &(cy, cx)) in pending.into_iter().zip(&cands) {
            let p = rx.recv().map_err(|_| anyhow::anyhow!("dropped"))??;
            let prob = p.data()[0];
            if best.map_or(true, |(bp, _)| prob > bp) {
                best = Some((prob, (cy, cx)));
            }
        }
        // "found" if the best candidate lands near an injected ball
        if let (Some((_, (by, bx))), false) = (best, truths.is_empty()) {
            if truths
                .iter()
                .any(|&(ty, tx)| by.abs_diff(ty) < PATCH / 2 && bx.abs_diff(tx) < PATCH / 2)
            {
                total_hits += 1;
            }
        }
        frame_lat.push(frame_start.elapsed().as_secs_f64() * 1e3);
        if f + 1 < n_frames {
            let next = run_start + frame_gap * (f as u32 + 1);
            if let Some(d) = next.checked_duration_since(Instant::now()) {
                std::thread::sleep(d);
            }
        }
    }

    frame_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean: f64 = frame_lat.iter().sum::<f64>() / frame_lat.len() as f64;
    let p95 = frame_lat[(0.95 * (frame_lat.len() - 1) as f64) as usize];
    let wall = run_start.elapsed().as_secs_f64();
    println!("\n== E2E-ball results ({n_frames} frames @ {offered_fps} offered fps)");
    println!("patches/frame:     {:.1}", total_patches as f64 / n_frames as f64);
    println!("frame latency:     mean {mean:.2} ms, p95 {p95:.2} ms (budget at 30 fps: 33.3 ms)");
    println!("classified:        {total_patches} patches in {wall:.2}s = {:.0} patches/s",
        total_patches as f64 / wall);
    println!("balls recovered:   {total_hits} frames with a correct top candidate");
    println!("pipeline startup:  {:.1} ms (incl. runtime JIT compile)", t0.elapsed().as_secs_f64() * 1e3);
    print!("\nserving metrics:\n{}", coord.render_metrics());
    coord.shutdown();
    Ok(())
}
