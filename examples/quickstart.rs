//! Quickstart: load the B-Human ball classifier, compile it at runtime via
//! PJRT (the paper's JIT step), run inference, and cross-check against the
//! exact interpreter — the 60-second tour of the public API.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use compiled_nn::compiler::exec::{CompileOptions, OptInterp};
use compiled_nn::model::load::load_model;
use compiled_nn::nn::interp::NaiveInterp;
use compiled_nn::nn::tensor::Tensor;
use compiled_nn::runtime::artifact::Manifest;
use compiled_nn::runtime::executor::{CompiledModel, Runtime};
use compiled_nn::util::rng::SplitMix64;

fn main() -> anyhow::Result<()> {
    // 1. The artifact manifest written by `make artifacts` (python runs
    //    once, never on the request path).
    let manifest = Manifest::load_default()?;
    let entry = manifest.entry("c_bh")?;
    println!("c_bh: {} params, batch buckets {:?}, weights baked: {}",
        entry.params, entry.batches, entry.baked);

    // 2. Runtime JIT: HLO text → native code, timed like Table 1's last row.
    let rt = Runtime::new()?;
    let model = CompiledModel::load(&rt, &manifest, "c_bh")?;
    println!("compiled in {:.1} ms (parse + XLA codegen per bucket)", model.total_compile_ms());

    // 3. Classify a batch of 8 synthetic 32×32 patches.
    let mut rng = SplitMix64::new(42);
    let x = Tensor::from_vec(&[8, 32, 32, 1], rng.uniform_vec(8 * 32 * 32));
    let out = model.execute(&rt, &x)?;
    println!("ball probabilities: {:?}",
        out[0].data().iter().map(|v| (v * 1000.0).round() / 1000.0).collect::<Vec<_>>());

    // 4. Cross-check the same batch against both interpreter engines.
    let spec = load_model(&manifest.models_dir, "c_bh")?;
    let exact = NaiveInterp::new(spec.clone())?.infer(&x)?;
    let mut opt = OptInterp::new(&spec, CompileOptions::default())?;
    let fast = opt.infer(&x)?;
    println!("compiled  vs exact: max |Δ| = {:.2e}", exact[0].max_abs_diff(&out[0]));
    println!("optimized vs exact: max |Δ| = {:.2e}", exact[0].max_abs_diff(&fast[0]));
    println!("(differences bounded by the §3.4 approximations — see `compiled-nn precision`)");
    Ok(())
}
